//! Compressed Sparse Row matrices and COO edge lists.
//!
//! Conventions (shared with `python/compile/kernels/ref.py` and the native
//! backend): an entry `(r, c, w)` of a matrix `S` contributes
//! `out[r] += w * x[c]` under SpMM.  The edge-list form used by the XLA
//! executables stores that entry as `src = c, dst = r`.
//!
//! The paper's Figure 5 "slicing" operation — rebuilding Rowptr/Col when
//! only a subset of *columns* is kept — is realized here two ways:
//! [`Csr::slice_columns`] (the literal re-processing, kept for the
//! slicing-cost benchmark) and [`Csr::transposed_edges_for_rows`] (the
//! cheap row-gather on the transposed matrix the hot path uses): RSC
//! selects column-row pairs of Â^T, i.e. rows of Â, and the retained
//! FLOPs are exactly the nnz of the selected rows.
//!
//! # Parallelism
//!
//! The heavy builders (`from_triples` sort, `transpose`, the two slicing
//! operations, `row_norms`) consult the process-wide
//! [`Parallelism`](crate::util::parallel::Parallelism) default and fan out
//! over rayon when the matrix is large enough; each also has an explicit
//! `*_with` variant taking the config.  All parallel paths produce output
//! byte-identical to the sequential one for any thread count: work is
//! partitioned by disjoint output ranges and the triple sort is stable
//! (see DESIGN.md §Parallel runtime).

use crate::util::parallel::{self, Parallelism};
use crate::util::rng::Rng;
use rayon::prelude::*;

/// COO edge list, ready to feed an XLA spmm executable (after padding to a
/// bucket capacity).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct EdgeList {
    pub src: Vec<i32>,
    pub dst: Vec<i32>,
    pub w: Vec<f32>,
}

impl EdgeList {
    pub fn len(&self) -> usize {
        self.src.len()
    }

    pub fn is_empty(&self) -> bool {
        self.src.is_empty()
    }

    pub fn with_capacity(n: usize) -> Self {
        EdgeList {
            src: Vec::with_capacity(n),
            dst: Vec::with_capacity(n),
            w: Vec::with_capacity(n),
        }
    }

    pub fn push(&mut self, src: i32, dst: i32, w: f32) {
        self.src.push(src);
        self.dst.push(dst);
        self.w.push(w);
    }

    /// Zero-pad (w = 0, indices 0) up to `cap` entries in place.
    pub fn pad_to(&mut self, cap: usize) {
        assert!(cap >= self.len(), "cap {cap} < len {}", self.len());
        self.src.resize(cap, 0);
        self.dst.resize(cap, 0);
        self.w.resize(cap, 0.0);
    }
}

/// Square CSR matrix (adjacency-shaped).
#[derive(Debug, Clone, PartialEq)]
pub struct Csr {
    pub n: usize,
    pub rowptr: Vec<usize>,
    pub col: Vec<u32>,
    pub val: Vec<f32>,
}

impl Csr {
    /// Build from (row, col, val) triples; duplicates are summed.
    /// Uses the process-wide [`Parallelism`] default for the sort.
    pub fn from_triples(n: usize, triples: Vec<(u32, u32, f32)>) -> Csr {
        Csr::from_triples_with(n, triples, parallel::global())
    }

    /// Fallible [`Csr::from_triples`] for *untrusted* triples (loaders,
    /// samplers): rejects out-of-range node indices and non-finite edge
    /// weights with an error naming the offending triple, instead of the
    /// debug-only assert (release: silent OOB rowptr) of the trusted
    /// by-construction path.
    pub fn try_from_triples(n: usize, triples: Vec<(u32, u32, f32)>) -> crate::Result<Csr> {
        Csr::try_from_triples_with(n, triples, parallel::global())
    }

    /// [`Csr::try_from_triples`] with an explicit parallelism config.
    pub fn try_from_triples_with(
        n: usize,
        mut triples: Vec<(u32, u32, f32)>,
        par: Parallelism,
    ) -> crate::Result<Csr> {
        // `corrupt_triple` fault point (util/fault.rs): poison one edge
        // weight at ingestion — validation below must reject it cleanly
        if crate::util::fault::fires_any("corrupt_triple").is_some() {
            if let Some(t) = triples.first_mut() {
                t.2 = f32::NAN;
            }
        }
        for (i, &(r, c, w)) in triples.iter().enumerate() {
            anyhow::ensure!(
                (r as usize) < n && (c as usize) < n,
                "triple {i}: node index ({r}, {c}) out of range for {n} nodes"
            );
            anyhow::ensure!(
                w.is_finite(),
                "triple {i}: non-finite edge weight {w} on edge ({r}, {c})"
            );
        }
        Ok(Csr::from_triples_with(n, triples, par))
    }

    /// [`Csr::from_triples`] with an explicit parallelism config.  The
    /// sort is *stable* on both paths, so duplicate (r, c) entries merge
    /// in input order and results are identical sequential vs parallel.
    /// Indices are trusted (callers construct them by iteration over an
    /// existing graph) — untrusted input goes through
    /// [`Csr::try_from_triples`].
    pub fn from_triples_with(
        n: usize,
        mut triples: Vec<(u32, u32, f32)>,
        par: Parallelism,
    ) -> Csr {
        if par.should_parallelize(triples.len()) {
            triples.par_sort_by_key(|&(r, c, _)| (r, c));
        } else {
            triples.sort_by_key(|&(r, c, _)| (r, c));
        }
        let mut rowptr = vec![0usize; n + 1];
        let mut col = Vec::with_capacity(triples.len());
        let mut val: Vec<f32> = Vec::with_capacity(triples.len());
        for &(r, c, w) in &triples {
            debug_assert!((r as usize) < n && (c as usize) < n);
            col.push(c);
            val.push(w);
            rowptr[r as usize + 1] += 1;
        }
        // prefix-sum rowptr
        for i in 0..n {
            rowptr[i + 1] += rowptr[i];
        }
        let mut m = Csr { n, rowptr, col, val };
        m.merge_duplicates();
        m
    }

    fn merge_duplicates(&mut self) {
        let mut new_rowptr = vec![0usize; self.n + 1];
        let mut new_col = Vec::with_capacity(self.col.len());
        let mut new_val = Vec::with_capacity(self.val.len());
        for r in 0..self.n {
            let (lo, hi) = (self.rowptr[r], self.rowptr[r + 1]);
            let mut i = lo;
            while i < hi {
                let c = self.col[i];
                let mut w = self.val[i];
                let mut j = i + 1;
                while j < hi && self.col[j] == c {
                    w += self.val[j];
                    j += 1;
                }
                new_col.push(c);
                new_val.push(w);
                i = j;
            }
            new_rowptr[r + 1] = new_col.len();
        }
        self.rowptr = new_rowptr;
        self.col = new_col;
        self.val = new_val;
    }

    pub fn nnz(&self) -> usize {
        self.col.len()
    }

    pub fn row(&self, r: usize) -> (&[u32], &[f32]) {
        let (lo, hi) = (self.rowptr[r], self.rowptr[r + 1]);
        (&self.col[lo..hi], &self.val[lo..hi])
    }

    pub fn row_nnz(&self, r: usize) -> usize {
        self.rowptr[r + 1] - self.rowptr[r]
    }

    /// Structural invariant check (used by property tests).
    pub fn validate(&self) -> bool {
        if self.rowptr.len() != self.n + 1 || self.rowptr[0] != 0 {
            return false;
        }
        if *self.rowptr.last().unwrap() != self.col.len() || self.col.len() != self.val.len() {
            return false;
        }
        for r in 0..self.n {
            if self.rowptr[r] > self.rowptr[r + 1] {
                return false;
            }
            let (cols, _) = self.row(r);
            for w in cols.windows(2) {
                if w[0] >= w[1] {
                    return false; // strictly sorted, no duplicates
                }
            }
            if cols.iter().any(|&c| c as usize >= self.n) {
                return false;
            }
        }
        true
    }

    /// Transpose, using the process-wide [`Parallelism`] default.
    pub fn transpose(&self) -> Csr {
        self.transpose_with(parallel::global())
    }

    /// [`Csr::transpose`] with an explicit parallelism config.
    ///
    /// The parallel path runs the same stable counting sort the
    /// sequential cursor walk performs, but materializes only the slot
    /// *permutation* sequentially (one u32 per entry, scratch-arena
    /// backed); the heavy (col, val) scatter then becomes a parallel
    /// ordered gather over disjoint slot ranges.  Slot assignment math
    /// is unchanged, so the output is byte-identical for any worker
    /// count.
    pub fn transpose_with(&self, par: Parallelism) -> Csr {
        let nnz = self.nnz();
        let mut counts = vec![0usize; self.n + 1];
        for &c in &self.col {
            counts[c as usize + 1] += 1;
        }
        for i in 0..self.n {
            counts[i + 1] += counts[i];
        }
        let rowptr = counts;
        let mut col = vec![0u32; nnz];
        let mut val = vec![0f32; nnz];
        if !par.should_parallelize(nnz) || self.n == 0 {
            let mut cursor = rowptr[..self.n].to_vec();
            for r in 0..self.n {
                let (cs, ws) = self.row(r);
                for (&c, &w) in cs.iter().zip(ws) {
                    let slot = cursor[c as usize];
                    col[slot] = r as u32;
                    val[slot] = w;
                    cursor[c as usize] += 1;
                }
            }
            return Csr { n: self.n, rowptr, col, val };
        }
        parallel::with_u32(nnz, |erow| {
            // entry id -> source row (expansion of the source rowptr)
            for r in 0..self.n {
                for e in self.rowptr[r]..self.rowptr[r + 1] {
                    erow[e] = r as u32;
                }
            }
            parallel::with_u32(nnz, |order| {
                // stable counting sort of entry ids by column
                parallel::with_usize(self.n, |cursor| {
                    cursor.copy_from_slice(&rowptr[..self.n]);
                    for (e, &c) in self.col.iter().enumerate() {
                        order[cursor[c as usize]] = e as u32;
                        cursor[c as usize] += 1;
                    }
                });
                let ch = par.chunk_rows(nnz);
                col.par_chunks_mut(ch)
                    .zip(val.par_chunks_mut(ch))
                    .enumerate()
                    .for_each(|(ci, (cc, vc))| {
                        let base = ci * ch;
                        for o in 0..cc.len() {
                            let e = order[base + o] as usize;
                            cc[o] = erow[e];
                            vc[o] = self.val[e];
                        }
                    });
            });
        });
        Csr { n: self.n, rowptr, col, val }
    }

    /// Shared self-loop builder: every off-diagonal entry with its
    /// original weight (or `off` when given), plus one `diag`-weighted
    /// self loop per row (duplicates merged by `from_triples`).
    fn add_self_loops_with(&self, off: Option<f32>, diag: f32) -> Csr {
        let mut triples = Vec::with_capacity(self.nnz() + self.n);
        for r in 0..self.n {
            let (cs, ws) = self.row(r);
            for (&c, &w) in cs.iter().zip(ws) {
                triples.push((r as u32, c, off.unwrap_or(w)));
            }
            triples.push((r as u32, r as u32, diag));
        }
        Csr::from_triples(self.n, triples)
    }

    /// A + I (unit diagonal added; existing diagonal summed).
    pub fn add_self_loops(&self) -> Csr {
        self.add_self_loops_with(None, 1.0)
    }

    /// GCN normalization: D^{-1/2} (A + I) D^{-1/2}, D = deg(A + I).
    pub fn gcn_normalize(&self) -> Csr {
        let a = self.add_self_loops();
        let mut deg = vec![0f32; a.n];
        for r in 0..a.n {
            let (_, ws) = a.row(r);
            deg[r] = ws.iter().sum::<f32>();
        }
        let inv_sqrt: Vec<f32> = deg
            .iter()
            .map(|&d| if d > 0.0 { 1.0 / d.sqrt() } else { 0.0 })
            .collect();
        let mut out = a.clone();
        for r in 0..a.n {
            let (lo, hi) = (a.rowptr[r], a.rowptr[r + 1]);
            for i in lo..hi {
                out.val[i] = inv_sqrt[r] * a.val[i] * inv_sqrt[a.col[i] as usize];
            }
        }
        out
    }

    /// MEAN normalization (Appendix A.3): D^{-1} (A + I) — each row of the
    /// result averages over in-neighbours incl. self.
    pub fn mean_normalize(&self) -> Csr {
        let a = self.add_self_loops();
        let mut out = a.clone();
        for r in 0..a.n {
            let (lo, hi) = (a.rowptr[r], a.rowptr[r + 1]);
            let deg = (hi - lo) as f32;
            for i in lo..hi {
                out.val[i] = a.val[i] / deg;
            }
        }
        out
    }

    /// GIN sum aggregation: `A + (1 + eps) I` with unit off-diagonal
    /// weights.  The `(1+eps)·h` self term of GIN-eps is folded into the
    /// self-loop weight, and a linear per-layer "MLP" commutes with the
    /// aggregation (`A (H W) = (A H) W`), so the fused `gcn_fwd`
    /// executables serve GIN unchanged over this matrix.
    pub fn gin_normalize(&self, eps: f32) -> Csr {
        self.add_self_loops_with(Some(1.0), 1.0 + eps)
    }

    /// L2 norm of each row's values (process-wide parallelism default).
    pub fn row_norms(&self) -> Vec<f32> {
        self.row_norms_with(parallel::global())
    }

    /// [`Csr::row_norms`] with an explicit parallelism config.
    pub fn row_norms_with(&self, par: Parallelism) -> Vec<f32> {
        let one = |r: usize| -> f32 {
            let (_, ws) = self.row(r);
            ws.iter().map(|w| w * w).sum::<f32>().sqrt()
        };
        if par.should_parallelize(self.nnz()) {
            (0..self.n).into_par_iter().map(one).collect()
        } else {
            (0..self.n).map(one).collect()
        }
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f32 {
        self.val.iter().map(|w| w * w).sum::<f32>().sqrt()
    }

    /// Full edge list for `out[r] += w * x[c]` (src = col, dst = row).
    pub fn to_edge_list(&self) -> EdgeList {
        let mut e = EdgeList::with_capacity(self.nnz());
        for r in 0..self.n {
            let (cs, ws) = self.row(r);
            for (&c, &w) in cs.iter().zip(ws) {
                e.push(c as i32, r as i32, w);
            }
        }
        e
    }

    /// Edge list of the *transpose* restricted to the given rows of self —
    /// the RSC sampled backward operand.  For every selected row `i` of
    /// this matrix, entry (i, u, w) becomes the transposed edge
    /// `out[u] += w * g[i]`, i.e. `src = i, dst = u`.
    ///
    /// Cost is O(sum of selected rows' nnz): this is the cheap,
    /// cache-amortized realization of the paper's Figure 5 slicing.
    /// Uses the process-wide [`Parallelism`] default; this is the sample
    /// cache's refresh hot path.
    pub fn transposed_edges_for_rows(&self, rows: &[u32]) -> EdgeList {
        self.transposed_edges_for_rows_with(rows, parallel::global())
    }

    /// [`Csr::transposed_edges_for_rows`] with an explicit parallelism
    /// config: selected rows are split into ranges, each worker gathers
    /// into its precomputed disjoint output slice (identical layout to
    /// the sequential append order).
    pub fn transposed_edges_for_rows_with(&self, rows: &[u32], par: Parallelism) -> EdgeList {
        let nnz: usize = rows.iter().map(|&r| self.row_nnz(r as usize)).sum();
        if !par.should_parallelize(nnz) || rows.is_empty() {
            let mut e = EdgeList::with_capacity(nnz);
            for &r in rows {
                let (cs, ws) = self.row(r as usize);
                for (&c, &w) in cs.iter().zip(ws) {
                    e.push(r as i32, c as i32, w);
                }
            }
            return e;
        }
        let mut e = EdgeList {
            src: vec![0; nnz],
            dst: vec![0; nnz],
            w: vec![0.0; nnz],
        };
        let rchunk = par.chunk_rows(rows.len());
        let row_chunks: Vec<&[u32]> = rows.chunks(rchunk).collect();
        let sizes: Vec<usize> = row_chunks
            .iter()
            .map(|ch| ch.iter().map(|&r| self.row_nnz(r as usize)).sum())
            .collect();
        let src_chunks = parallel::split_varsize(&mut e.src, sizes.iter().copied());
        let dst_chunks = parallel::split_varsize(&mut e.dst, sizes.iter().copied());
        let w_chunks = parallel::split_varsize(&mut e.w, sizes.iter().copied());
        src_chunks
            .into_par_iter()
            .zip(dst_chunks)
            .zip(w_chunks)
            .zip(row_chunks)
            .for_each(|(((sc, dc), wc), ch)| {
                let mut k = 0;
                for &r in ch {
                    let (cs, ws) = self.row(r as usize);
                    for (&c, &w) in cs.iter().zip(ws) {
                        sc[k] = r as i32;
                        dc[k] = c as i32;
                        wc[k] = w;
                        k += 1;
                    }
                }
            });
        e
    }

    /// Paper Figure 5: rebuild a CSR keeping only the given columns
    /// (re-processing Rowptr/Col/Val).  Provided for the slicing-cost
    /// benchmark; the hot path uses [`Csr::transposed_edges_for_rows`].
    /// Uses the process-wide [`Parallelism`] default.
    pub fn slice_columns(&self, keep: &[bool]) -> Csr {
        self.slice_columns_with(keep, parallel::global())
    }

    /// [`Csr::slice_columns`] with an explicit parallelism config
    /// (two-pass: parallel per-row counts, prefix sum, parallel fill into
    /// disjoint row ranges — same output as the sequential single pass).
    pub fn slice_columns_with(&self, keep: &[bool], par: Parallelism) -> Csr {
        assert_eq!(keep.len(), self.n);
        if !par.should_parallelize(self.nnz()) {
            let mut rowptr = vec![0usize; self.n + 1];
            let mut col = Vec::new();
            let mut val = Vec::new();
            for r in 0..self.n {
                let (cs, ws) = self.row(r);
                for (&c, &w) in cs.iter().zip(ws) {
                    if keep[c as usize] {
                        col.push(c);
                        val.push(w);
                    }
                }
                rowptr[r + 1] = col.len();
            }
            return Csr { n: self.n, rowptr, col, val };
        }
        // pass 1: kept-entry count per row
        let counts: Vec<usize> = (0..self.n)
            .into_par_iter()
            .map(|r| {
                let (cs, _) = self.row(r);
                cs.iter().filter(|&&c| keep[c as usize]).count()
            })
            .collect();
        let mut rowptr = vec![0usize; self.n + 1];
        for r in 0..self.n {
            rowptr[r + 1] = rowptr[r] + counts[r];
        }
        let kept_nnz = rowptr[self.n];
        let mut col = vec![0u32; kept_nnz];
        let mut val = vec![0f32; kept_nnz];
        // pass 2: fill disjoint per-chunk output ranges
        let rchunk = par.chunk_rows(self.n);
        let starts: Vec<usize> = (0..self.n).step_by(rchunk).collect();
        let sizes: Vec<usize> = starts
            .iter()
            .map(|&r0| rowptr[(r0 + rchunk).min(self.n)] - rowptr[r0])
            .collect();
        let col_chunks = parallel::split_varsize(&mut col, sizes.iter().copied());
        let val_chunks = parallel::split_varsize(&mut val, sizes.iter().copied());
        col_chunks
            .into_par_iter()
            .zip(val_chunks)
            .zip(starts)
            .for_each(|((cc, vc), r0)| {
                let mut k = 0;
                for r in r0..(r0 + rchunk).min(self.n) {
                    let (cs, ws) = self.row(r);
                    for (&c, &w) in cs.iter().zip(ws) {
                        if keep[c as usize] {
                            cc[k] = c;
                            vc[k] = w;
                            k += 1;
                        }
                    }
                }
            });
        Csr { n: self.n, rowptr, col, val }
    }

    /// Relabel nodes by a [`Permutation`](crate::graph::Permutation):
    /// entry `(r, c, w)` becomes `(new(r), new(c), w)`.  Values are moved,
    /// never recombined (a bijection cannot create duplicate positions),
    /// so the permuted matrix holds the exact same weight multiset; each
    /// new row's columns are re-sorted ascending as the CSR invariant
    /// requires.  This is the one-shot reordering pass of the vectorized
    /// locality layer (see `graph/reorder.rs`).
    pub fn permute(&self, p: &crate::graph::Permutation) -> Csr {
        assert_eq!(p.len(), self.n, "permutation size mismatch");
        let mut triples = Vec::with_capacity(self.nnz());
        for r in 0..self.n {
            let (cs, ws) = self.row(r);
            let nr = p.new_of_old(r) as u32;
            for (&c, &w) in cs.iter().zip(ws) {
                triples.push((nr, p.new_of_old(c as usize) as u32, w));
            }
        }
        Csr::from_triples(self.n, triples)
    }

    /// Matrix bandwidth: max |row - col| over stored entries (0 when
    /// empty).  Reordering diagnostic — RCM exists to shrink this.
    pub fn bandwidth(&self) -> usize {
        let mut bw = 0usize;
        for r in 0..self.n {
            let (cs, _) = self.row(r);
            for &c in cs {
                bw = bw.max(r.abs_diff(c as usize));
            }
        }
        bw
    }

    /// Dense dump (tests only).
    pub fn to_dense(&self) -> Vec<Vec<f32>> {
        let mut d = vec![vec![0f32; self.n]; self.n];
        for r in 0..self.n {
            let (cs, ws) = self.row(r);
            for (&c, &w) in cs.iter().zip(ws) {
                d[r][c as usize] += w;
            }
        }
        d
    }

    /// Random sparse matrix (tests / property checks).
    pub fn random(n: usize, nnz: usize, rng: &mut Rng) -> Csr {
        let mut triples = Vec::with_capacity(nnz);
        for _ in 0..nnz {
            triples.push((
                rng.below(n) as u32,
                rng.below(n) as u32,
                rng.normal_f32(),
            ));
        }
        Csr::from_triples(n, triples)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    fn small() -> Csr {
        // Figure 3's 4-node example matrix A^T (values 1.0).
        // rows: 0:{1}, 1:{0,2,3}, 2:{1}, 3:{1,2}  (an arbitrary sparse pattern)
        Csr::from_triples(
            4,
            vec![
                (0, 1, 1.0),
                (1, 0, 1.0),
                (1, 2, 1.0),
                (1, 3, 1.0),
                (2, 1, 1.0),
                (3, 1, 1.0),
                (3, 2, 1.0),
            ],
        )
    }

    #[test]
    fn build_and_validate() {
        let m = small();
        assert!(m.validate());
        assert_eq!(m.nnz(), 7);
        assert_eq!(m.row_nnz(1), 3);
    }

    #[test]
    fn duplicates_merge() {
        let m = Csr::from_triples(2, vec![(0, 1, 1.0), (0, 1, 2.0), (1, 0, 0.5)]);
        assert!(m.validate());
        assert_eq!(m.nnz(), 2);
        assert_eq!(m.row(0), (&[1u32][..], &[3.0f32][..]));
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Rng::new(11);
        for _ in 0..10 {
            let m = Csr::random(20, 60, &mut rng);
            assert!(m.transpose().validate());
            assert_eq!(m.transpose().transpose(), m);
        }
    }

    #[test]
    fn parallel_builders_match_sequential() {
        let seq = Parallelism::sequential();
        let par = Parallelism::with_threads(4).with_grain(1);
        let mut rng = Rng::new(31);
        for trial in 0..10 {
            let n = 5 + trial * 7;
            let m = Csr::random(n, 4 * n, &mut rng);
            assert_eq!(m.transpose_with(seq), m.transpose_with(par), "transpose n={n}");
            assert_eq!(m.row_norms_with(seq), m.row_norms_with(par));
            let keep: Vec<bool> = (0..n).map(|i| i % 3 != 0).collect();
            assert_eq!(
                m.slice_columns_with(&keep, seq),
                m.slice_columns_with(&keep, par)
            );
            let rows: Vec<u32> = (0..n as u32).filter(|r| r % 2 == 0).collect();
            assert_eq!(
                m.transposed_edges_for_rows_with(&rows, seq),
                m.transposed_edges_for_rows_with(&rows, par)
            );
        }
        // degenerate shapes
        let empty = Csr::from_triples_with(3, vec![], par);
        assert!(empty.validate());
        assert_eq!(empty.transpose_with(par), empty);
        let single = Csr::from_triples_with(1, vec![(0, 0, 2.5)], par);
        assert_eq!(single.transpose_with(seq), single.transpose_with(par));
    }

    #[test]
    fn self_loops_diag() {
        let m = small().add_self_loops();
        assert!(m.validate());
        for r in 0..4 {
            let (cs, _) = m.row(r);
            assert!(cs.contains(&(r as u32)));
        }
        assert_eq!(m.nnz(), 11);
    }

    #[test]
    fn gcn_normalize_symmetric_rows_sum() {
        // For a symmetric A, Â should be symmetric too.
        let a = Csr::from_triples(
            3,
            vec![(0, 1, 1.0), (1, 0, 1.0), (1, 2, 1.0), (2, 1, 1.0)],
        );
        let norm = a.gcn_normalize();
        let d = norm.to_dense();
        for i in 0..3 {
            for j in 0..3 {
                assert!((d[i][j] - d[j][i]).abs() < 1e-6);
            }
        }
        // known value: hat a_01 = 1/sqrt(2*3)
        assert!((d[0][1] - 1.0 / (6.0f32).sqrt()).abs() < 1e-6);
    }

    #[test]
    fn mean_normalize_rows_sum_to_one() {
        let m = small().mean_normalize();
        for r in 0..4 {
            let (_, ws) = m.row(r);
            let s: f32 = ws.iter().sum();
            assert!((s - 1.0).abs() < 1e-6);
        }
    }

    #[test]
    fn gin_normalize_unit_weights_and_eps_self_loops() {
        let m = small();
        let g = m.gin_normalize(0.5);
        assert_eq!(g.nnz(), m.nnz() + m.n, "A + I structure");
        for r in 0..g.n {
            let (cs, ws) = g.row(r);
            for (&c, &w) in cs.iter().zip(ws) {
                if c as usize == r {
                    assert_eq!(w, 1.5, "self loop carries 1 + eps");
                } else {
                    assert_eq!(w, 1.0, "off-diagonal sum weights are 1");
                }
            }
        }
        assert!(g.validate());
    }

    #[test]
    fn edge_list_matches_dense() {
        let m = small();
        let e = m.to_edge_list();
        assert_eq!(e.len(), m.nnz());
        let d = m.to_dense();
        for i in 0..e.len() {
            assert_eq!(d[e.dst[i] as usize][e.src[i] as usize], e.w[i]);
        }
    }

    #[test]
    fn transposed_edges_selected_rows() {
        let m = small();
        let e = m.transposed_edges_for_rows(&[1, 3]);
        assert_eq!(e.len(), m.row_nnz(1) + m.row_nnz(3));
        // all srcs are from the selected set
        assert!(e.src.iter().all(|&s| s == 1 || s == 3));
    }

    #[test]
    fn slice_columns_matches_dense_masking() {
        let m = small();
        let keep = vec![false, true, false, true];
        let s = m.slice_columns(&keep);
        assert!(s.validate());
        let d0 = m.to_dense();
        let d1 = s.to_dense();
        for r in 0..4 {
            for c in 0..4 {
                let want = if keep[c] { d0[r][c] } else { 0.0 };
                assert_eq!(d1[r][c], want);
            }
        }
    }

    #[test]
    fn pad_edges() {
        let mut e = small().to_edge_list();
        let n0 = e.len();
        e.pad_to(n0 + 5);
        assert_eq!(e.len(), n0 + 5);
        assert!(e.w[n0..].iter().all(|&w| w == 0.0));
    }

    #[test]
    fn permute_preserves_rows_and_values() {
        let mut rng = Rng::new(17);
        let m = Csr::random(20, 60, &mut rng);
        // identity is a no-op
        let id = crate::graph::Permutation::identity(20);
        assert_eq!(m.permute(&id), m);
        // random relabeling: valid CSR, same nnz, rows map through
        let mut order: Vec<u32> = (0..20).collect();
        rng.shuffle(&mut order);
        let p = crate::graph::Permutation::from_order(order);
        let pm = m.permute(&p);
        assert!(pm.validate());
        assert_eq!(pm.nnz(), m.nnz());
        for new in 0..20 {
            let old = p.old_of_new(new);
            assert_eq!(pm.row_nnz(new), m.row_nnz(old), "row {new}<-{old}");
            let (cs, ws) = m.row(old);
            let mut want: Vec<(u32, f32)> = cs
                .iter()
                .map(|&c| p.new_of_old(c as usize) as u32)
                .zip(ws.iter().copied())
                .collect();
            want.sort_by_key(|&(c, _)| c);
            let (pcs, pws) = pm.row(new);
            let got: Vec<(u32, f32)> =
                pcs.iter().copied().zip(pws.iter().copied()).collect();
            assert_eq!(got, want);
        }
    }

    #[test]
    fn prop_csr_invariants_random() {
        prop::check("csr-invariants", 40, |rng| {
            let n = rng.range(1, 40);
            let nnz = rng.below(4 * n + 1);
            let m = Csr::random(n, nnz, rng);
            assert!(m.validate());
            assert!(m.transpose().validate());
            assert!(m.gcn_normalize().validate());
            // fro norm matches dense
            let dense_sq: f32 = m
                .to_dense()
                .iter()
                .flatten()
                .map(|w| w * w)
                .sum();
            assert!((m.fro_norm() - dense_sq.sqrt()).abs() < 1e-3);
        });
    }

    #[test]
    fn prop_transposed_edges_equal_slice_semantics() {
        // transposed_edges_for_rows(S) must equal the full transposed edge
        // list of the column-sliced transpose — the Figure 5 equivalence.
        prop::check("slice-equivalence", 30, |rng| {
            let n = rng.range(2, 30);
            let m = Csr::random(n, 3 * n, rng);
            let mut keep = vec![false; n];
            let sel: Vec<u32> = (0..n)
                .filter(|_| rng.chance(0.4))
                .map(|i| i as u32)
                .collect();
            for &s in &sel {
                keep[s as usize] = true;
            }
            let t = m.transpose();
            let sliced = t.slice_columns(&keep); // keep columns of A^T = rows of A
            let mut a: Vec<(i32, i32, f32)> = {
                let e = m.transposed_edges_for_rows(&sel);
                (0..e.len()).map(|i| (e.src[i], e.dst[i], e.w[i])).collect()
            };
            let mut b: Vec<(i32, i32, f32)> = {
                let e = sliced.to_edge_list();
                (0..e.len())
                    .filter(|&i| e.w[i] != 0.0)
                    .map(|i| (e.src[i], e.dst[i], e.w[i]))
                    .collect()
            };
            a.sort_by(|x, y| (x.0, x.1).cmp(&(y.0, y.1)).then(x.2.total_cmp(&y.2)));
            b.sort_by(|x, y| (x.0, x.1).cmp(&(y.0, y.1)).then(x.2.total_cmp(&y.2)));
            assert_eq!(a, b);
        });
    }

    #[test]
    fn try_from_triples_validates_untrusted_input() {
        // clean triples build the same matrix as the trusted path
        let t = vec![(0u32, 1u32, 1.0f32), (1, 0, 2.0), (2, 2, 3.0)];
        let a = Csr::try_from_triples(3, t.clone()).unwrap();
        let b = Csr::from_triples(3, t);
        assert_eq!((a.rowptr, a.col, a.val), (b.rowptr, b.col, b.val));

        // out-of-range row, out-of-range col, NaN and infinite weights
        let err = Csr::try_from_triples(3, vec![(3, 0, 1.0)]).unwrap_err();
        assert!(err.to_string().contains("out of range"), "{err}");
        let err = Csr::try_from_triples(3, vec![(0, 7, 1.0)]).unwrap_err();
        assert!(err.to_string().contains("out of range"), "{err}");
        let err = Csr::try_from_triples(3, vec![(0, 1, f32::NAN)]).unwrap_err();
        assert!(err.to_string().contains("non-finite"), "{err}");
        let err = Csr::try_from_triples(3, vec![(0, 1, f32::INFINITY)]).unwrap_err();
        assert!(err.to_string().contains("non-finite"), "{err}");

        // the error names the offending triple's position
        let err =
            Csr::try_from_triples(3, vec![(0, 0, 1.0), (1, 1, 1.0), (2, 9, 1.0)]).unwrap_err();
        assert!(err.to_string().contains("triple 2"), "{err}");
    }
}
