//! Sparse-matrix substrate: CSR storage, edge lists (the exchange format
//! with the XLA executables), normalizations, locality-aware node
//! reordering, and the synthetic graph generator.

pub mod csr;
pub mod generate;
pub mod reorder;

pub use csr::{Csr, EdgeList};
pub use generate::{generate_power_law, generate_sbm, PowerLawConfig, PowerLawGraph, SbmConfig};
pub use reorder::{degree_order, rcm_order, Permutation, ReorderKind};
