//! Sparse-matrix substrate: CSR storage, edge lists (the exchange format
//! with the XLA executables), normalizations, and the synthetic graph
//! generator.

pub mod csr;
pub mod generate;

pub use csr::{Csr, EdgeList};
pub use generate::{generate_sbm, SbmConfig};
