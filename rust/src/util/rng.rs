//! Deterministic RNG substrate (rand replacement): PCG64-ish generator
//! (xoshiro256**), SplitMix64 seeding, and the distributions the data
//! generators and samplers need.  All experiment code takes explicit
//! seeds so every table/figure is reproducible.

/// xoshiro256** — fast, high-quality, 2^256 period.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
    /// Cached spare normal deviate (Box–Muller produces pairs).
    spare: Option<f64>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare: None }
    }

    /// Derive an independent stream (for per-trial / per-layer RNGs).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    /// Full generator state (xoshiro words + cached Box–Muller spare)
    /// for checkpointing; [`Rng::from_state`] restores a bit-identical
    /// continuation of the stream.
    pub fn state(&self) -> ([u64; 4], Option<f64>) {
        (self.s, self.spare)
    }

    /// Rebuild a generator from [`Rng::state`] output.
    pub fn from_state(s: [u64; 4], spare: Option<f64>) -> Rng {
        Rng { s, spare }
    }

    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire).
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0);
        let n = n as u64;
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as usize
    }

    /// Uniform in [lo, hi).
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo)
    }

    /// Standard normal (Box–Muller, cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(v) = self.spare.take() {
            return v;
        }
        loop {
            let u = 2.0 * self.f64() - 1.0;
            let v = 2.0 * self.f64() - 1.0;
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                let f = (-2.0 * s.ln() / s).sqrt();
                self.spare = Some(v * f);
                return u * f;
            }
        }
    }

    pub fn normal_f32(&mut self) -> f32 {
        self.normal() as f32
    }

    /// Bernoulli draw.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        debug_assert!(total > 0.0);
        let mut target = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            target -= w;
            if target <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.below(i + 1);
            items.swap(i, j);
        }
    }

    /// k distinct indices from [0, n) (partial Fisher–Yates on an index map).
    pub fn sample_distinct(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = self.range(i, n);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// Zipf-like power-law weight for node v (used to skew SBM degrees).
    pub fn fill_normal_f32(&mut self, out: &mut [f32], mean: f32, std: f32) {
        for x in out.iter_mut() {
            *x = mean + std * self.normal_f32();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_bounds() {
        let mut r = Rng::new(1);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            let n = r.below(17);
            assert!(n < 17);
        }
    }

    #[test]
    fn uniform_coverage() {
        let mut r = Rng::new(2);
        let mut counts = [0usize; 8];
        for _ in 0..80_000 {
            counts[r.below(8)] += 1;
        }
        for &c in &counts {
            assert!((8_000..12_000).contains(&c), "{counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let n = 100_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            sum += x;
            sq += x * x;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn distinct_sampling() {
        let mut r = Rng::new(4);
        let s = r.sample_distinct(100, 30);
        assert_eq!(s.len(), 30);
        let mut t = s.clone();
        t.sort_unstable();
        t.dedup();
        assert_eq!(t.len(), 30);
    }

    #[test]
    fn weighted_prefers_heavy() {
        let mut r = Rng::new(5);
        let w = [1.0, 0.0, 9.0];
        let mut c = [0usize; 3];
        for _ in 0..10_000 {
            c[r.weighted(&w)] += 1;
        }
        assert_eq!(c[1], 0);
        assert!(c[2] > 5 * c[0]);
    }

    #[test]
    fn state_roundtrip_continues_the_stream() {
        let mut r = Rng::new(9);
        r.normal(); // populate the Box–Muller spare
        let (s, spare) = r.state();
        assert!(spare.is_some());
        let mut twin = Rng::from_state(s, spare);
        for _ in 0..10 {
            assert_eq!(r.normal().to_bits(), twin.normal().to_bits());
            assert_eq!(r.next_u64(), twin.next_u64());
        }
    }

    #[test]
    fn fork_streams_differ() {
        let mut r = Rng::new(6);
        let mut a = r.fork(1);
        let mut b = r.fork(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
