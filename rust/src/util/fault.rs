//! Deterministic fault-injection substrate (`fault-inject` feature).
//!
//! Named one-shot fault points that the robustness tests arm to prove
//! each recovery path end-to-end (DESIGN.md §Fault tolerance):
//!
//! | fault point               | arg         | fires in                                      |
//! |---------------------------|-------------|-----------------------------------------------|
//! | `refresh_panic@step`      | due step    | a background refresh build (worker panic)     |
//! | `nan_site@k`              | site index  | the site's backward-SpMM output (NaN fill)    |
//! | `torn_checkpoint_write`   | —           | checkpoint save: half-written temp, no rename |
//! | `corrupt_checkpoint_byte` | byte offset | checkpoint save: flips one byte after rename  |
//!
//! Faults are armed programmatically ([`arm`] / [`arm_spec`]) or through
//! the `RSC_FAULTS` environment variable (comma-separated specs, e.g.
//! `RSC_FAULTS=refresh_panic@3,torn_checkpoint_write`); the `rsc train
//! --faults <spec>` flag is the CLI spelling.  Every armed fault fires at
//! most once, so a recovered run proceeds healthy afterwards — which is
//! exactly what the recovery tests assert.
//!
//! Without the `fault-inject` feature every function here compiles to an
//! inlined no-op: the hot path carries no cost and production builds
//! cannot be armed at all (`--faults` reports a clear error instead).

/// True when the crate was built with `--features fault-inject`.
pub const ENABLED: bool = cfg!(feature = "fault-inject");

#[cfg(feature = "fault-inject")]
mod imp {
    use crate::Result;
    use anyhow::{anyhow, ensure};
    use std::sync::Mutex;

    #[derive(Debug, Clone)]
    struct Fault {
        name: String,
        arg: Option<u64>,
    }

    static ARMED: Mutex<Vec<Fault>> = Mutex::new(Vec::new());

    fn armed() -> std::sync::MutexGuard<'static, Vec<Fault>> {
        // a panic while the lock is held is exactly what this harness
        // provokes on purpose; tolerate poisoning instead of compounding
        ARMED.lock().unwrap_or_else(|e| e.into_inner())
    }

    fn env_init() {
        use std::sync::Once;
        static INIT: Once = Once::new();
        INIT.call_once(|| {
            if let Ok(spec) = std::env::var("RSC_FAULTS") {
                if let Err(e) = arm_spec(&spec) {
                    panic!("RSC_FAULTS: {e}");
                }
            }
        });
    }

    /// Arm one fault point; `arg` of `None` matches any argument.
    pub fn arm(name: &str, arg: Option<u64>) {
        armed().push(Fault {
            name: name.to_string(),
            arg,
        });
    }

    /// Arm a comma-separated list of `name` / `name@arg` specs.
    pub fn arm_spec(spec: &str) -> Result<()> {
        for part in spec.split(',').map(str::trim).filter(|p| !p.is_empty()) {
            match part.split_once('@') {
                Some((name, arg)) => {
                    ensure!(!name.is_empty(), "bad fault spec {part:?}: empty name");
                    let arg = arg
                        .parse::<u64>()
                        .map_err(|_| anyhow!("bad fault spec {part:?}: arg must be a u64"))?;
                    arm(name, Some(arg));
                }
                None => arm(part, None),
            }
        }
        Ok(())
    }

    /// Disarm everything (each test starts from a clean slate).
    pub fn clear() {
        armed().clear();
    }

    /// Number of armed-but-unfired faults (tests pin this to 0 at the
    /// end to prove the injection actually happened).
    pub fn armed_count() -> usize {
        env_init();
        armed().len()
    }

    /// One-shot check: true exactly once for an armed fault whose name
    /// matches and whose armed arg (if any) equals `arg`.
    pub fn fires(name: &str, arg: u64) -> bool {
        env_init();
        let mut a = armed();
        if let Some(i) = a
            .iter()
            .position(|f| f.name == name && f.arg.is_none_or(|x| x == arg))
        {
            a.remove(i);
            return true;
        }
        false
    }

    /// One-shot check ignoring the argument; returns the armed argument
    /// (itself optional) when the fault fires.
    pub fn fires_any(name: &str) -> Option<Option<u64>> {
        env_init();
        let mut a = armed();
        let i = a.iter().position(|f| f.name == name)?;
        Some(a.remove(i).arg)
    }

    /// Panic on the calling thread if `name@arg` is armed.
    pub fn maybe_panic(name: &str, arg: u64) {
        if fires(name, arg) {
            panic!("fault injected: {name}@{arg}");
        }
    }

    /// Fill `data` with NaN if `name@arg` is armed; the watchdog tests
    /// poison a site's backward-SpMM output through this.
    pub fn poison_f32s(name: &str, arg: u64, data: &mut [f32]) -> bool {
        if !fires(name, arg) {
            return false;
        }
        for x in data.iter_mut() {
            *x = f32::NAN;
        }
        true
    }
}

#[cfg(not(feature = "fault-inject"))]
mod imp {
    //! No-op twins: same signatures, nothing armed, nothing fires.
    use crate::Result;

    #[inline(always)]
    pub fn arm(_name: &str, _arg: Option<u64>) {}

    #[inline(always)]
    pub fn arm_spec(_spec: &str) -> Result<()> {
        Ok(())
    }

    #[inline(always)]
    pub fn clear() {}

    #[inline(always)]
    pub fn armed_count() -> usize {
        0
    }

    #[inline(always)]
    pub fn fires(_name: &str, _arg: u64) -> bool {
        false
    }

    #[inline(always)]
    pub fn fires_any(_name: &str) -> Option<Option<u64>> {
        None
    }

    #[inline(always)]
    pub fn maybe_panic(_name: &str, _arg: u64) {}

    #[inline(always)]
    pub fn poison_f32s(_name: &str, _arg: u64, _data: &mut [f32]) -> bool {
        false
    }
}

pub use imp::*;

#[cfg(test)]
mod tests {
    use super::*;

    // One sequential test: the registry is process-global, and sibling
    // #[test]s run as parallel threads in the same process.
    #[test]
    fn registry_semantics_match_the_feature_gate() {
        clear();
        if ENABLED {
            arm("refresh_panic", Some(3));
            arm_spec(" nan_site@1 , torn_checkpoint_write ").unwrap();
            assert_eq!(armed_count(), 3);
            assert!(!fires("refresh_panic", 2), "arg must match");
            assert!(fires("refresh_panic", 3));
            assert!(!fires("refresh_panic", 3), "faults are one-shot");
            let mut buf = [1.0f32, 2.0];
            assert!(poison_f32s("nan_site", 1, &mut buf));
            assert!(buf.iter().all(|x| x.is_nan()));
            assert_eq!(fires_any("torn_checkpoint_write"), Some(None));
            assert_eq!(fires_any("torn_checkpoint_write"), None);
            assert_eq!(armed_count(), 0);
            assert!(arm_spec("nan_site@notanumber").is_err());
            assert!(arm_spec("@3").is_err());
        } else {
            // feature off: arming is inert and nothing ever fires
            arm("refresh_panic", Some(3));
            arm_spec("nan_site@1").unwrap();
            assert_eq!(armed_count(), 0);
            assert!(!fires("refresh_panic", 3));
            assert_eq!(fires_any("torn_checkpoint_write"), None);
            let mut buf = [1.0f32];
            assert!(!poison_f32s("nan_site", 1, &mut buf));
            assert_eq!(buf, [1.0]);
            maybe_panic("refresh_panic", 3); // must not panic
        }
        clear();
    }
}
