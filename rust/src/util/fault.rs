//! Deterministic fault-injection substrate (`fault-inject` feature).
//!
//! Named fault points that the robustness tests arm to prove each
//! recovery path end-to-end (DESIGN.md §Fault tolerance).  PR 9 grew the
//! registry from one-shot points into seeded *schedules* so the chaos
//! soak (`rsc soak`) can sustain faults across a whole run:
//!
//! | fault point               | arg         | fires in                                      |
//! |---------------------------|-------------|-----------------------------------------------|
//! | `refresh_panic@step`      | due step    | a background refresh build (worker panic)     |
//! | `refresh_stall@ms`        | sleep ms    | a background refresh build (sleeps past SLA)  |
//! | `slow_worker@ms`          | sleep ms    | any supervised background task (slow start)   |
//! | `nan_site@k`              | site index  | the site's backward-SpMM output (NaN fill)    |
//! | `corrupt_triple`          | —           | triple ingestion (poisons one edge weight)    |
//! | `checkpoint_save_fail`    | —           | checkpoint save: fails before writing         |
//! | `torn_checkpoint_write`   | —           | checkpoint save: half-written temp, no rename |
//! | `corrupt_checkpoint_byte` | byte offset | checkpoint save: flips one byte after rename  |
//!
//! ## Schedule grammar
//!
//! Each comma-separated spec is `name` plus an optional `@` suffix:
//!
//! | spec            | trigger                                              |
//! |-----------------|------------------------------------------------------|
//! | `name`          | one-shot, any argument matches                       |
//! | `name@123`      | one-shot, only argument `123` matches                |
//! | `name@every:N`  | recurring: every Nth matching check fires            |
//! | `name@at:N`     | the Nth matching check fires, then disarms           |
//! | `name@p:0.05`   | each matching check fires with probability `p`, from |
//! |                 | a dedicated xoshiro stream (see [`seed_stream`])     |
//!
//! Schedule forms (`every:`/`at:`/`p:`) match any argument; only the
//! plain `name@u64` form pins the argument.  Probabilistic triggers draw
//! from a stream seeded by [`seed_stream`], so a soak episode that seeds
//! the stream and arms the same spec replays the same firing pattern.
//!
//! Faults are armed programmatically ([`arm`] / [`arm_spec`]) or through
//! the `RSC_FAULTS` environment variable (comma-separated specs, e.g.
//! `RSC_FAULTS=refresh_panic@3,nan_site@every:5`); the `rsc train
//! --faults <spec>` flag is the CLI spelling.  `RSC_FAULTS` is validated
//! once at startup by [`init_from_env`] — a bad spec is a clean CLI
//! error, never a panic inside the lazy registry init.
//!
//! Without the `fault-inject` feature every function here compiles to an
//! inlined no-op: the hot path carries no cost and production builds
//! cannot be armed at all (`--faults` and `RSC_FAULTS` report a clear
//! error instead).

/// True when the crate was built with `--features fault-inject`.
pub const ENABLED: bool = cfg!(feature = "fault-inject");

/// Stall duration used by [`maybe_stall`] when the armed fault carries
/// no explicit millisecond argument (the schedule forms).
pub const DEFAULT_STALL_MS: u64 = 150;

#[cfg(feature = "fault-inject")]
mod imp {
    use super::DEFAULT_STALL_MS;
    use crate::util::rng::Rng;
    use crate::Result;
    use anyhow::{anyhow, bail, ensure};
    use std::sync::Mutex;

    #[derive(Debug, Clone)]
    enum Trigger {
        /// Fires on the first matching check, then disarms.
        Once,
        /// Fires on every `n`th matching check, forever.
        Every { n: u64, count: u64 },
        /// Fires on exactly the `n`th matching check, then disarms.
        At { n: u64, count: u64 },
        /// Fires each matching check with probability `p` (seeded stream).
        Prob { p: f64 },
    }

    #[derive(Debug, Clone)]
    struct Fault {
        name: String,
        arg: Option<u64>,
        trigger: Trigger,
    }

    struct State {
        faults: Vec<Fault>,
        /// Dedicated stream for `@p:` triggers; lazily created, reset by
        /// `seed_stream` so probabilistic schedules replay byte-for-byte.
        rng: Option<Rng>,
        env_done: bool,
        env_err: Option<String>,
    }

    static STATE: Mutex<State> = Mutex::new(State {
        faults: Vec::new(),
        rng: None,
        env_done: false,
        env_err: None,
    });

    fn state() -> std::sync::MutexGuard<'static, State> {
        // a panic while the lock is held is exactly what this harness
        // provokes on purpose; tolerate poisoning instead of compounding
        let mut st = STATE.lock().unwrap_or_else(|e| e.into_inner());
        if !st.env_done {
            st.env_done = true;
            if let Ok(spec) = std::env::var("RSC_FAULTS") {
                match parse_spec(&spec) {
                    Ok(fs) => st.faults.extend(fs),
                    Err(e) => st.env_err = Some(format!("{e:#}")),
                }
            }
        }
        st
    }

    fn parse_one(part: &str) -> Result<Fault> {
        let fault = |arg, trigger| Fault {
            name: String::new(),
            arg,
            trigger,
        };
        let (name, f) = match part.split_once('@') {
            None => (part, fault(None, Trigger::Once)),
            Some((name, rest)) => {
                let f = if let Some(n) = rest.strip_prefix("every:") {
                    let n = n
                        .parse::<u64>()
                        .map_err(|_| anyhow!("bad fault spec {part:?}: every:N needs a u64"))?;
                    ensure!(n >= 1, "bad fault spec {part:?}: every:N needs N >= 1");
                    fault(None, Trigger::Every { n, count: 0 })
                } else if let Some(n) = rest.strip_prefix("at:") {
                    let n = n
                        .parse::<u64>()
                        .map_err(|_| anyhow!("bad fault spec {part:?}: at:N needs a u64"))?;
                    ensure!(n >= 1, "bad fault spec {part:?}: at:N needs N >= 1");
                    fault(None, Trigger::At { n, count: 0 })
                } else if let Some(p) = rest.strip_prefix("p:") {
                    let p = p
                        .parse::<f64>()
                        .map_err(|_| anyhow!("bad fault spec {part:?}: p:X needs a float"))?;
                    ensure!(
                        p > 0.0 && p <= 1.0,
                        "bad fault spec {part:?}: p must be in (0, 1]"
                    );
                    fault(None, Trigger::Prob { p })
                } else {
                    let arg = rest.parse::<u64>().map_err(|_| {
                        anyhow!("bad fault spec {part:?}: arg must be a u64, every:N, at:N or p:X")
                    })?;
                    fault(Some(arg), Trigger::Once)
                };
                (name, f)
            }
        };
        ensure!(!name.is_empty(), "bad fault spec {part:?}: empty name");
        Ok(Fault {
            name: name.to_string(),
            ..f
        })
    }

    /// Parse a comma-separated list of schedule specs without arming
    /// anything (startup validation goes through here).
    fn parse_spec(spec: &str) -> Result<Vec<Fault>> {
        spec.split(',')
            .map(str::trim)
            .filter(|p| !p.is_empty())
            .map(parse_one)
            .collect()
    }

    /// Validate `RSC_FAULTS` (if set) and surface a parse failure as a
    /// clean error.  `main` calls this once at startup so a bad spec is
    /// a CLI diagnostic instead of a panic inside the registry.
    pub fn init_from_env() -> Result<()> {
        let st = state();
        if let Some(e) = &st.env_err {
            bail!("RSC_FAULTS: {e}");
        }
        Ok(())
    }

    /// Arm one one-shot fault point; `arg` of `None` matches any
    /// argument.
    pub fn arm(name: &str, arg: Option<u64>) {
        state().faults.push(Fault {
            name: name.to_string(),
            arg,
            trigger: Trigger::Once,
        });
    }

    /// Arm a comma-separated list of schedule specs (see the module doc
    /// for the grammar).
    pub fn arm_spec(spec: &str) -> Result<()> {
        let fs = parse_spec(spec)?;
        state().faults.extend(fs);
        Ok(())
    }

    /// Seed the dedicated stream that drives `@p:` triggers.  Soak
    /// episodes call this before arming so probabilistic schedules are
    /// reproducible run-to-run.
    pub fn seed_stream(seed: u64) {
        state().rng = Some(Rng::new(seed ^ 0x5EED_FA17));
    }

    /// Disarm everything (each test / soak episode starts clean).
    pub fn clear() {
        state().faults.clear();
    }

    /// Number of armed faults.  One-shot faults leave the registry when
    /// they fire (tests pin this to 0 to prove the injection actually
    /// happened); recurring schedules stay armed.
    pub fn armed_count() -> usize {
        state().faults.len()
    }

    /// Evaluate a trigger for one matching check; returns (fired,
    /// disarm).
    fn step_trigger(t: &mut Trigger, rng: &mut Option<Rng>) -> (bool, bool) {
        match t {
            Trigger::Once => (true, true),
            Trigger::Every { n, count } => {
                *count += 1;
                (*count % *n == 0, false)
            }
            Trigger::At { n, count } => {
                *count += 1;
                (*count == *n, *count == *n)
            }
            Trigger::Prob { p } => {
                let r = rng.get_or_insert_with(|| Rng::new(0x5EED_FA17));
                (r.chance(*p), false)
            }
        }
    }

    /// Check the first armed fault whose name matches and whose armed
    /// arg (if any) equals `arg`; advances its schedule and reports
    /// whether it fires on this check.
    pub fn fires(name: &str, arg: u64) -> bool {
        let mut st = state();
        let st = &mut *st;
        let Some(i) = st
            .faults
            .iter()
            .position(|f| f.name == name && f.arg.is_none_or(|x| x == arg))
        else {
            return false;
        };
        let (fired, disarm) = step_trigger(&mut st.faults[i].trigger, &mut st.rng);
        if disarm {
            st.faults.remove(i);
        }
        fired
    }

    /// Like [`fires`] but ignores the argument; returns the armed
    /// argument (itself optional) when the fault fires on this check.
    pub fn fires_any(name: &str) -> Option<Option<u64>> {
        let mut st = state();
        let st = &mut *st;
        let i = st.faults.iter().position(|f| f.name == name)?;
        let (fired, disarm) = step_trigger(&mut st.faults[i].trigger, &mut st.rng);
        let arg = st.faults[i].arg;
        if disarm {
            st.faults.remove(i);
        }
        fired.then_some(arg)
    }

    /// Panic on the calling thread if `name@arg` fires.
    pub fn maybe_panic(name: &str, arg: u64) {
        if fires(name, arg) {
            panic!("fault injected: {name}@{arg}");
        }
    }

    /// Sleep on the calling thread if `name` fires, simulating a stalled
    /// or slow worker.  The armed argument is the sleep in milliseconds
    /// ([`DEFAULT_STALL_MS`] for schedule forms, which carry no arg).
    pub fn maybe_stall(name: &str) -> bool {
        let Some(arg) = fires_any(name) else {
            return false;
        };
        let ms = arg.unwrap_or(DEFAULT_STALL_MS);
        std::thread::sleep(std::time::Duration::from_millis(ms));
        true
    }

    /// Fill `data` with NaN if `name@arg` fires; the watchdog tests
    /// poison a site's backward-SpMM output through this.
    pub fn poison_f32s(name: &str, arg: u64, data: &mut [f32]) -> bool {
        if !fires(name, arg) {
            return false;
        }
        for x in data.iter_mut() {
            *x = f32::NAN;
        }
        true
    }
}

#[cfg(not(feature = "fault-inject"))]
mod imp {
    //! No-op twins: same signatures, nothing armed, nothing fires.
    use crate::Result;
    use anyhow::bail;

    #[inline(always)]
    pub fn init_from_env() -> Result<()> {
        if std::env::var("RSC_FAULTS").is_ok_and(|s| !s.trim().is_empty()) {
            bail!("RSC_FAULTS requires a build with --features fault-inject");
        }
        Ok(())
    }

    #[inline(always)]
    pub fn arm(_name: &str, _arg: Option<u64>) {}

    #[inline(always)]
    pub fn arm_spec(_spec: &str) -> Result<()> {
        Ok(())
    }

    #[inline(always)]
    pub fn seed_stream(_seed: u64) {}

    #[inline(always)]
    pub fn clear() {}

    #[inline(always)]
    pub fn armed_count() -> usize {
        0
    }

    #[inline(always)]
    pub fn fires(_name: &str, _arg: u64) -> bool {
        false
    }

    #[inline(always)]
    pub fn fires_any(_name: &str) -> Option<Option<u64>> {
        None
    }

    #[inline(always)]
    pub fn maybe_panic(_name: &str, _arg: u64) {}

    #[inline(always)]
    pub fn maybe_stall(_name: &str) -> bool {
        false
    }

    #[inline(always)]
    pub fn poison_f32s(_name: &str, _arg: u64, _data: &mut [f32]) -> bool {
        false
    }
}

pub use imp::*;

#[cfg(test)]
mod tests {
    use super::*;

    // One sequential test: the registry is process-global, and sibling
    // #[test]s run as parallel threads in the same process.
    #[test]
    fn registry_semantics_match_the_feature_gate() {
        clear();
        if ENABLED {
            one_shot_semantics();
            schedule_semantics();
            probabilistic_replay();
            parse_errors();
        } else {
            // feature off: arming is inert and nothing ever fires
            arm("refresh_panic", Some(3));
            arm_spec("nan_site@1").unwrap();
            arm_spec("nan_site@every:2,nan_site@p:0.5").unwrap();
            assert_eq!(armed_count(), 0);
            assert!(!fires("refresh_panic", 3));
            assert_eq!(fires_any("torn_checkpoint_write"), None);
            let mut buf = [1.0f32];
            assert!(!poison_f32s("nan_site", 1, &mut buf));
            assert_eq!(buf, [1.0]);
            assert!(!maybe_stall("refresh_stall"));
            maybe_panic("refresh_panic", 3); // must not panic
            seed_stream(7);
        }
        clear();
    }

    #[cfg(feature = "fault-inject")]
    fn one_shot_semantics() {
        arm("refresh_panic", Some(3));
        arm_spec(" nan_site@1 , torn_checkpoint_write ").unwrap();
        assert_eq!(armed_count(), 3);
        assert!(!fires("refresh_panic", 2), "arg must match");
        assert!(fires("refresh_panic", 3));
        assert!(!fires("refresh_panic", 3), "faults are one-shot");
        let mut buf = [1.0f32, 2.0];
        assert!(poison_f32s("nan_site", 1, &mut buf));
        assert!(buf.iter().all(|x| x.is_nan()));
        assert_eq!(fires_any("torn_checkpoint_write"), Some(None));
        assert_eq!(fires_any("torn_checkpoint_write"), None);
        assert_eq!(armed_count(), 0);
    }

    #[cfg(feature = "fault-inject")]
    fn schedule_semantics() {
        clear();
        arm_spec("nan_site@every:3").unwrap();
        let pattern: Vec<bool> = (0..7).map(|_| fires("nan_site", 0)).collect();
        assert_eq!(
            pattern,
            [false, false, true, false, false, true, false],
            "every:3 fires on the 3rd and 6th checks"
        );
        assert_eq!(armed_count(), 1, "recurring schedules stay armed");

        clear();
        arm_spec("checkpoint_save_fail@at:2").unwrap();
        assert_eq!(fires_any("checkpoint_save_fail"), None);
        assert_eq!(fires_any("checkpoint_save_fail"), Some(None));
        assert_eq!(fires_any("checkpoint_save_fail"), None, "at:N disarms");
        assert_eq!(armed_count(), 0);

        clear();
        arm("refresh_stall", Some(1)); // 1 ms: keep the test fast
        assert!(maybe_stall("refresh_stall"));
        assert!(!maybe_stall("refresh_stall"));
    }

    #[cfg(feature = "fault-inject")]
    fn probabilistic_replay() {
        clear();
        let run = || {
            seed_stream(7);
            arm_spec("nan_site@p:0.5").unwrap();
            let pat: Vec<bool> = (0..32).map(|_| fires("nan_site", 0)).collect();
            clear();
            pat
        };
        let a = run();
        let b = run();
        assert_eq!(a, b, "seeded p: schedule replays identically");
        assert!(a.iter().any(|&x| x) && a.iter().any(|&x| !x));
    }

    #[cfg(feature = "fault-inject")]
    fn parse_errors() {
        assert!(arm_spec("nan_site@notanumber").is_err());
        assert!(arm_spec("@3").is_err());
        assert!(arm_spec("@every:2").is_err());
        assert!(arm_spec("x@every:0").is_err());
        assert!(arm_spec("x@every:abc").is_err());
        assert!(arm_spec("x@at:0").is_err());
        assert!(arm_spec("x@p:0").is_err());
        assert!(arm_spec("x@p:1.5").is_err());
        assert!(arm_spec("x@p:abc").is_err());
    }
}
