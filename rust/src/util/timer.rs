//! Wall-clock timing: a scoped stopwatch plus a label→duration accumulator
//! used by the trainer to attribute step time to op classes (SpMM fwd,
//! SpMM bwd, MatMul, loss, Adam, sampling, allocation) — the raw data for
//! Figure 1, Table 2 and every speedup column.

use std::collections::BTreeMap;
use std::time::{Duration, Instant};

#[derive(Debug, Clone, Copy)]
pub struct Stopwatch(Instant);

impl Stopwatch {
    pub fn start() -> Self {
        Stopwatch(Instant::now())
    }

    pub fn elapsed(&self) -> Duration {
        self.0.elapsed()
    }

    pub fn ms(&self) -> f64 {
        self.elapsed().as_secs_f64() * 1e3
    }
}

/// Seconds since the Unix epoch.  The one sanctioned `SystemTime` read in
/// the crate (rule R05): callers that want an absolute timestamp (bench
/// reports, log lines) go through here instead of touching the wall clock
/// from kernel or library code.
pub fn unix_time_s() -> u64 {
    std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0)
}

/// An injectable monotonic-elapsed-seconds source, so wall-clock-driven
/// policies (the trainer's `--checkpoint-mins` cadence) can be unit-tested
/// with a fake clock while the real implementation stays confined to this
/// module (rule R05).
pub trait Clock {
    /// Seconds elapsed since the clock's origin (first call or creation).
    fn elapsed_s(&mut self) -> u64;

    /// Milliseconds elapsed since the clock's origin.  The default is
    /// second-granular (good enough for scripted [`FakeClock`] tests);
    /// [`WallClock`] overrides it with a precise reading for the stall
    /// watchdog.
    fn elapsed_ms(&mut self) -> u64 {
        self.elapsed_s() * 1000
    }
}

/// The real thing: lazily starts a [`Stopwatch`] on first read.
#[derive(Debug, Default)]
pub struct WallClock(Option<Stopwatch>);

impl WallClock {
    pub fn new() -> Self {
        WallClock(None)
    }
}

impl Clock for WallClock {
    fn elapsed_s(&mut self) -> u64 {
        let sw = self.0.get_or_insert_with(Stopwatch::start);
        sw.elapsed().as_secs()
    }

    fn elapsed_ms(&mut self) -> u64 {
        let sw = self.0.get_or_insert_with(Stopwatch::start);
        sw.elapsed().as_millis() as u64
    }
}

/// Scripted clock for tests: returns the programmed readings in order and
/// repeats the last one when exhausted.
#[derive(Debug, Default)]
pub struct FakeClock {
    readings: Vec<u64>,
    i: usize,
}

impl FakeClock {
    pub fn new(readings: &[u64]) -> Self {
        FakeClock {
            readings: readings.to_vec(),
            i: 0,
        }
    }
}

impl Clock for FakeClock {
    fn elapsed_s(&mut self) -> u64 {
        let r = self
            .readings
            .get(self.i)
            .or(self.readings.last())
            .copied()
            .unwrap_or(0);
        if self.i < self.readings.len() {
            self.i += 1;
        }
        r
    }
}

/// Accumulates durations per label.
#[derive(Debug, Default, Clone)]
pub struct TimeBook {
    totals: BTreeMap<String, Duration>,
    counts: BTreeMap<String, u64>,
}

impl TimeBook {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&mut self, label: &str, d: Duration) {
        *self.totals.entry(label.to_string()).or_default() += d;
        *self.counts.entry(label.to_string()).or_default() += 1;
    }

    /// Time `f`, attributing its duration to `label`.
    pub fn scope<T>(&mut self, label: &str, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        self.add(label, t0.elapsed());
        out
    }

    pub fn total_ms(&self, label: &str) -> f64 {
        self.totals
            .get(label)
            .map(|d| d.as_secs_f64() * 1e3)
            .unwrap_or(0.0)
    }

    pub fn count(&self, label: &str) -> u64 {
        self.counts.get(label).copied().unwrap_or(0)
    }

    pub fn mean_ms(&self, label: &str) -> f64 {
        let c = self.count(label);
        if c == 0 {
            0.0
        } else {
            self.total_ms(label) / c as f64
        }
    }

    pub fn labels(&self) -> impl Iterator<Item = &str> {
        self.totals.keys().map(|s| s.as_str())
    }

    pub fn grand_total_ms(&self) -> f64 {
        self.totals.values().map(|d| d.as_secs_f64() * 1e3).sum()
    }

    pub fn merge(&mut self, other: &TimeBook) {
        for (k, v) in &other.totals {
            *self.totals.entry(k.clone()).or_default() += *v;
        }
        for (k, v) in &other.counts {
            *self.counts.entry(k.clone()).or_default() += *v;
        }
    }

    pub fn clear(&mut self) {
        self.totals.clear();
        self.counts.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulates() {
        let mut tb = TimeBook::new();
        tb.add("spmm", Duration::from_millis(10));
        tb.add("spmm", Duration::from_millis(20));
        tb.add("mm", Duration::from_millis(5));
        assert_eq!(tb.count("spmm"), 2);
        assert!((tb.total_ms("spmm") - 30.0).abs() < 1e-9);
        assert!((tb.mean_ms("spmm") - 15.0).abs() < 1e-9);
        assert!((tb.grand_total_ms() - 35.0).abs() < 1e-9);
    }

    #[test]
    fn scope_measures() {
        let mut tb = TimeBook::new();
        let v = tb.scope("work", || {
            std::thread::sleep(Duration::from_millis(2));
            42
        });
        assert_eq!(v, 42);
        assert!(tb.total_ms("work") >= 1.0);
    }

    #[test]
    fn fake_clock_replays_then_repeats() {
        let mut c = FakeClock::new(&[0, 61, 130]);
        assert_eq!(c.elapsed_s(), 0);
        assert_eq!(c.elapsed_s(), 61);
        assert_eq!(c.elapsed_s(), 130);
        assert_eq!(c.elapsed_s(), 130);
        let mut empty = FakeClock::new(&[]);
        assert_eq!(empty.elapsed_s(), 0);
        let mut w = WallClock::new();
        assert_eq!(w.elapsed_s(), 0);
    }

    #[test]
    fn elapsed_ms_defaults_to_second_granularity() {
        let mut c = FakeClock::new(&[2, 3]);
        assert_eq!(c.elapsed_ms(), 2000);
        assert_eq!(c.elapsed_ms(), 3000);
        let mut w = WallClock::new();
        assert!(w.elapsed_ms() < 1000, "wall override reads real ms");
    }

    #[test]
    fn merge_adds() {
        let mut a = TimeBook::new();
        a.add("x", Duration::from_millis(1));
        let mut b = TimeBook::new();
        b.add("x", Duration::from_millis(2));
        a.merge(&b);
        assert_eq!(a.count("x"), 2);
    }
}
