//! Runtime health ladder (DESIGN.md §Chaos soak & health ladder).
//!
//! A small state machine the trainer threads through every step to turn
//! *sustained* fault pressure into graceful degradation — and, crucially,
//! back into full service once the pressure stops:
//!
//! ```text
//!             clean×K                clean×K
//!   Healthy <-------- Degraded <-------- ExactOnly      Halted
//!      |                 ^  |               ^  |           ^
//!      | trip/panic/     |  | trip streak   |  | retry-on- |
//!      | stall/save-fail |  | >= 3          |  | exact     |
//!      +-----------------+  +---------------+  | failed or |
//!                                              | save-fail |
//!                                              | streak>=3 |
//!                                              +-----------+
//! ```
//!
//! - **Healthy** — full pipeline: prefetched background builds, sampled
//!   sites per the allocator.
//! - **Degraded** — background prefetch is switched off (builds run on
//!   the synchronous fallback, which is bit-identical by the prefetch
//!   parity contract), everything else unchanged.
//! - **ExactOnly** — additionally every site is forced onto the exact
//!   path (a sliding `force_exact_until` window), trading speed for a
//!   numerically conservative regime.
//! - **Halted** — terminal: training stops with a final checkpoint so
//!   the run can be resumed after the operator intervenes.
//!
//! Re-promotion climbs one rung per `promote_after` consecutive clean
//! steps, so a burst of faults degrades quickly but the run earns its
//! way back instead of staying degraded forever.  The ladder itself is
//! pure bookkeeping — every *effect* (prefetch toggle, forced-exact
//! window, halting) is applied by the trainer/engine, and each one is
//! bit-identical to the healthy pipeline by existing contracts, so the
//! ladder can never change a recoverable run's final weights.

/// Ladder rung, ordered from best to worst.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Health {
    Healthy,
    Degraded,
    ExactOnly,
    Halted,
}

impl Health {
    pub fn name(self) -> &'static str {
        match self {
            Health::Healthy => "healthy",
            Health::Degraded => "degraded",
            Health::ExactOnly => "exact-only",
            Health::Halted => "halted",
        }
    }

    /// One rung better (promotion target); `Halted` is terminal.
    fn promoted(self) -> Health {
        match self {
            Health::Healthy | Health::Degraded => Health::Healthy,
            Health::ExactOnly => Health::Degraded,
            Health::Halted => Health::Halted,
        }
    }
}

/// What the trainer observed during one step.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum HealthEvent {
    /// The step completed with finite loss/gradients and no incident.
    CleanStep,
    /// The NaN watchdog tripped (non-finite loss or gradients).
    WatchdogTrip,
    /// A background refresh worker panicked (past its respawn budget).
    WorkerPanic,
    /// The stall watchdog abandoned an overdue background build.
    RefreshStall,
    /// A checkpoint save failed.
    CheckpointSaveFailed,
    /// A checkpoint save succeeded (resets the save-failure streak).
    CheckpointSaved,
    /// Even the exact-path retry produced non-finite gradients.
    ExactRetryFailed,
}

/// One recorded rung change.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Transition {
    pub step: u64,
    pub from: Health,
    pub to: Health,
    pub cause: HealthEvent,
}

/// The ladder: feed it one or more [`HealthEvent`]s per step.
#[derive(Debug, Clone)]
pub struct HealthLadder {
    state: Health,
    /// Consecutive clean steps needed to climb one rung.
    promote_after: u64,
    clean_streak: u64,
    trip_streak: u64,
    save_fail_streak: u64,
    demotions: u64,
    repromotions: u64,
    transitions: Vec<Transition>,
}

/// Keep the transition log bounded even under pathological schedules;
/// oscillation is at most one demotion + one promotion per
/// `promote_after` steps, so real runs never get near this.
const MAX_TRANSITIONS: usize = 512;

impl HealthLadder {
    pub fn new(promote_after: u64) -> Self {
        HealthLadder {
            state: Health::Healthy,
            promote_after: promote_after.max(1),
            clean_streak: 0,
            trip_streak: 0,
            save_fail_streak: 0,
            demotions: 0,
            repromotions: 0,
            transitions: Vec::new(),
        }
    }

    pub fn state(&self) -> Health {
        self.state
    }

    pub fn is_halted(&self) -> bool {
        self.state == Health::Halted
    }

    /// True on `Degraded` or worse: the trainer keeps prefetch off.
    pub fn degraded_or_worse(&self) -> bool {
        self.state >= Health::Degraded
    }

    /// True on `ExactOnly` or worse: the trainer forces the exact path.
    pub fn exact_only_or_worse(&self) -> bool {
        self.state >= Health::ExactOnly
    }

    pub fn demotions(&self) -> u64 {
        self.demotions
    }

    pub fn repromotions(&self) -> u64 {
        self.repromotions
    }

    pub fn transitions(&self) -> &[Transition] {
        &self.transitions
    }

    fn move_to(&mut self, step: u64, to: Health, cause: HealthEvent) {
        if to == self.state {
            return;
        }
        if to > self.state {
            self.demotions += 1;
        } else {
            self.repromotions += 1;
        }
        if self.transitions.len() < MAX_TRANSITIONS {
            self.transitions.push(Transition {
                step,
                from: self.state,
                to,
                cause,
            });
        }
        self.state = to;
    }

    /// Demote to at least `floor` (never promotes).
    fn demote_to(&mut self, step: u64, floor: Health, cause: HealthEvent) {
        self.clean_streak = 0;
        if floor > self.state {
            self.move_to(step, floor, cause);
        }
    }

    /// Feed one observation; `step` is the trainer's global step counter
    /// (used only to label transitions).
    pub fn observe(&mut self, step: u64, event: HealthEvent) {
        if self.state == Health::Halted {
            return; // terminal
        }
        match event {
            HealthEvent::CleanStep => {
                self.trip_streak = 0;
                self.clean_streak += 1;
                if self.state != Health::Healthy && self.clean_streak >= self.promote_after {
                    self.clean_streak = 0;
                    self.move_to(step, self.state.promoted(), event);
                }
            }
            HealthEvent::WatchdogTrip => {
                self.trip_streak += 1;
                let floor = if self.trip_streak >= 3 {
                    Health::ExactOnly
                } else {
                    Health::Degraded
                };
                self.demote_to(step, floor, event);
            }
            HealthEvent::WorkerPanic | HealthEvent::RefreshStall => {
                self.demote_to(step, Health::Degraded, event);
            }
            HealthEvent::CheckpointSaveFailed => {
                self.save_fail_streak += 1;
                let floor = if self.save_fail_streak >= 3 {
                    Health::Halted
                } else {
                    Health::Degraded
                };
                self.demote_to(step, floor, event);
            }
            HealthEvent::CheckpointSaved => {
                self.save_fail_streak = 0;
            }
            HealthEvent::ExactRetryFailed => {
                self.demote_to(step, Health::Halted, event);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn clean_steps(l: &mut HealthLadder, from: u64, n: u64) {
        for s in 0..n {
            l.observe(from + s, HealthEvent::CleanStep);
        }
    }

    #[test]
    fn starts_healthy_and_stays_healthy_on_clean_steps() {
        let mut l = HealthLadder::new(3);
        clean_steps(&mut l, 0, 100);
        assert_eq!(l.state(), Health::Healthy);
        assert!(l.transitions().is_empty());
        assert_eq!(l.demotions(), 0);
        assert_eq!(l.repromotions(), 0);
    }

    #[test]
    fn single_trip_degrades_then_repromotes_after_k_clean_steps() {
        let mut l = HealthLadder::new(3);
        l.observe(5, HealthEvent::WatchdogTrip);
        assert_eq!(l.state(), Health::Degraded);
        clean_steps(&mut l, 6, 2);
        assert_eq!(l.state(), Health::Degraded, "needs K consecutive");
        l.observe(8, HealthEvent::CleanStep);
        assert_eq!(l.state(), Health::Healthy);
        assert_eq!(l.demotions(), 1);
        assert_eq!(l.repromotions(), 1);
        assert_eq!(l.transitions().len(), 2);
        assert_eq!(l.transitions()[1].from, Health::Degraded);
        assert_eq!(l.transitions()[1].to, Health::Healthy);
    }

    #[test]
    fn trip_streak_escalates_to_exact_only_and_climbs_back_one_rung_at_a_time() {
        let mut l = HealthLadder::new(2);
        for s in 0..3 {
            l.observe(s, HealthEvent::WatchdogTrip);
        }
        assert_eq!(l.state(), Health::ExactOnly);
        clean_steps(&mut l, 3, 2);
        assert_eq!(l.state(), Health::Degraded, "one rung per K clean steps");
        clean_steps(&mut l, 5, 2);
        assert_eq!(l.state(), Health::Healthy);
        assert_eq!(l.repromotions(), 2);
    }

    #[test]
    fn unclean_step_resets_the_promotion_streak() {
        let mut l = HealthLadder::new(3);
        l.observe(0, HealthEvent::WorkerPanic);
        assert_eq!(l.state(), Health::Degraded);
        clean_steps(&mut l, 1, 2);
        l.observe(3, HealthEvent::RefreshStall); // resets the streak
        clean_steps(&mut l, 4, 2);
        assert_eq!(l.state(), Health::Degraded);
        l.observe(6, HealthEvent::CleanStep);
        assert_eq!(l.state(), Health::Healthy);
    }

    #[test]
    fn exact_retry_failure_halts_terminally() {
        let mut l = HealthLadder::new(2);
        l.observe(7, HealthEvent::ExactRetryFailed);
        assert!(l.is_halted());
        clean_steps(&mut l, 8, 50);
        assert!(l.is_halted(), "halted is terminal");
        assert_eq!(l.transitions().len(), 1);
    }

    #[test]
    fn checkpoint_save_failures_halt_on_a_streak_but_reset_on_success() {
        let mut l = HealthLadder::new(2);
        l.observe(0, HealthEvent::CheckpointSaveFailed);
        l.observe(1, HealthEvent::CheckpointSaveFailed);
        assert_eq!(l.state(), Health::Degraded);
        l.observe(2, HealthEvent::CheckpointSaved); // streak resets
        l.observe(3, HealthEvent::CheckpointSaveFailed);
        l.observe(4, HealthEvent::CheckpointSaveFailed);
        assert_eq!(l.state(), Health::Degraded, "streak was reset");
        l.observe(5, HealthEvent::CheckpointSaveFailed);
        assert!(l.is_halted(), "3 consecutive save failures halt the run");
    }

    #[test]
    fn predicates_follow_the_rung_order() {
        let mut l = HealthLadder::new(2);
        assert!(!l.degraded_or_worse());
        l.observe(0, HealthEvent::WatchdogTrip);
        assert!(l.degraded_or_worse());
        assert!(!l.exact_only_or_worse());
        l.observe(1, HealthEvent::WatchdogTrip);
        l.observe(2, HealthEvent::WatchdogTrip);
        assert!(l.exact_only_or_worse());
        assert!(!l.is_halted());
        assert_eq!(l.state().name(), "exact-only");
    }

    #[test]
    fn promote_after_zero_is_clamped_to_one() {
        let mut l = HealthLadder::new(0);
        l.observe(0, HealthEvent::WorkerPanic);
        l.observe(1, HealthEvent::CleanStep);
        assert_eq!(l.state(), Health::Healthy);
    }
}
