//! Thread-parallelism substrate for the sparse hot paths.
//!
//! Two pieces:
//!
//! * [`Parallelism`] — the configuration every parallel kernel takes:
//!   thread count plus a *grain* (minimum work size, in scalar operations,
//!   below which the sequential path runs).  A process-wide default is
//!   held here ([`set_global`] / [`global`]) so deep call sites
//!   (CSR slicing inside the sample cache, top-k sorts inside the engine)
//!   inherit the CLI's `--threads` choice without signature churn.
//! * a per-thread **scratch arena** ([`with_f32`], [`with_u32`],
//!   [`with_usize`]) — reusable buffers for hot-loop temporaries (edge
//!   grouping tables, cursors, per-row partials) so repeated kernel calls
//!   stop allocating.  Buffers are thread-local, zeroed on hand-out, and
//!   returned to the pool when the closure exits; [`arena_stats`] reports
//!   reuse vs. fresh allocations.
//!
//! **Determinism contract** (see DESIGN.md §Parallel runtime): every
//! parallel kernel in this crate partitions *output* rows (or uses a
//! stable sort / stable counting order), so its result is byte-for-byte
//! identical to the sequential oracle for *any* thread count.  The
//! `Parallelism` value only decides how much hardware is used, never what
//! is computed.
//!
//! Thread-count resolution ([`Parallelism::auto`]) respects restricted
//! CPU budgets: `std::thread::available_parallelism` reads cgroup quotas
//! on Linux, so a 1-core container runs sequentially — the same concern
//! `runtime/xla.rs` handles for the TFRT Eigen pool.  The `RSC_THREADS`
//! env var overrides detection.

use std::cell::RefCell;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{OnceLock, RwLock};

/// Default minimum work size (scalar ops) before a kernel goes parallel.
/// Below this, thread fan-out costs more than the loop itself.
pub const DEFAULT_GRAIN: usize = 1 << 14;

/// How many parallel kernels may scale: a thread count and a work-size
/// threshold.  Cheap to copy; carried by [`crate::runtime::NativeBackend`]
/// and read from the process-wide default everywhere else.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Parallelism {
    threads: usize,
    grain: usize,
}

impl Parallelism {
    /// Single-threaded: every kernel takes its sequential path.
    pub fn sequential() -> Parallelism {
        Parallelism { threads: 1, grain: DEFAULT_GRAIN }
    }

    /// Exactly `n` workers (clamped to at least 1).
    pub fn with_threads(n: usize) -> Parallelism {
        Parallelism { threads: n.max(1), grain: DEFAULT_GRAIN }
    }

    /// Override the work-size threshold (tests use `with_grain(1)` to
    /// force the parallel path on tiny inputs).
    pub fn with_grain(mut self, grain: usize) -> Parallelism {
        self.grain = grain.max(1);
        self
    }

    /// Detect the usable core count: `RSC_THREADS` if set, else
    /// `available_parallelism` (which honours cgroup CPU quotas).
    pub fn auto() -> Parallelism {
        if let Some(n) = std::env::var("RSC_THREADS")
            .ok()
            .and_then(|v| v.parse::<usize>().ok())
            .filter(|&n| n > 0)
        {
            return Parallelism::with_threads(n);
        }
        let n = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1);
        Parallelism::with_threads(n)
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    pub fn grain(&self) -> usize {
        self.grain
    }

    pub fn is_parallel(&self) -> bool {
        self.threads > 1
    }

    /// Gate: should a kernel with `work` scalar operations fan out?
    /// Also makes sure the worker pool exists before the caller uses it.
    pub fn should_parallelize(&self, work: usize) -> bool {
        if self.threads > 1 && work >= self.grain {
            ensure_pool(self.threads);
            true
        } else {
            false
        }
    }

    /// Rows per parallel chunk when splitting `rows` output rows: a few
    /// chunks per worker for load balance, never zero.
    pub fn chunk_rows(&self, rows: usize) -> usize {
        let chunks = (self.threads * 4).max(1);
        rows.div_ceil(chunks).max(1)
    }
}

impl Default for Parallelism {
    /// The process-wide default (see [`global`]).
    fn default() -> Parallelism {
        global()
    }
}

// ---------------------------------------------------------------------
// process-wide default + worker pool
// ---------------------------------------------------------------------

static GLOBAL: RwLock<Option<Parallelism>> = RwLock::new(None);
static POOL: OnceLock<usize> = OnceLock::new();

/// Build the global rayon pool once, sized to the first configured
/// thread count.  Later `Parallelism` values with a different count still
/// compute identical results (determinism is thread-count independent);
/// only the hardware utilisation is fixed at first use.
fn ensure_pool(threads: usize) {
    POOL.get_or_init(|| {
        let _ = rayon::ThreadPoolBuilder::new()
            .num_threads(threads)
            .thread_name(|i| format!("rsc-par-{i}"))
            .build_global();
        threads
    });
}

/// Set the process-wide default (the CLI's `--threads`, a test harness,
/// or an embedding application).
pub fn set_global(p: Parallelism) {
    if p.is_parallel() {
        ensure_pool(p.threads());
    }
    *GLOBAL.write().unwrap() = Some(p);
}

/// Background tasks that panicked and were absorbed (process-global;
/// callers snapshot a delta per run).  A panic inside `rayon::spawn`
/// would otherwise abort the whole process — panic isolation turns it
/// into "the prefetch slot never fills", which the sample cache already
/// handles with the bit-identical synchronous build path.
static WORKER_PANICS: AtomicU64 = AtomicU64::new(0);

/// Total background-task panics absorbed so far in this process.
pub fn worker_panics() -> u64 {
    WORKER_PANICS.load(Ordering::Relaxed)
}

/// Run `task` on the shared rayon worker pool without blocking the
/// caller — the sample cache's prefetched refresh builds go through
/// here.  The pool is created on first use (sized to the process-wide
/// [`Parallelism`], minimum one worker, so even `--threads 1` runs keep
/// background builds off the training thread).  Tasks must own their
/// inputs (`'static`); determinism is unaffected because every build is
/// a pure function of its captured inputs (DESIGN.md §Parallel runtime).
/// A panicking task is caught and counted rather than aborting the
/// process (see [`worker_panics`]).
pub fn spawn_background(task: impl FnOnce() + Send + 'static) {
    ensure_pool(global().threads());
    rayon::spawn(move || {
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(task));
        if caught.is_err() {
            WORKER_PANICS.fetch_add(1, Ordering::Relaxed);
        }
    });
}

/// Background tasks that panicked and were re-run by the supervised
/// spawn path ([`spawn_background_retry`]); process-global like
/// [`worker_panics`], snapshot a delta per run.
static WORKER_RESPAWNS: AtomicU64 = AtomicU64::new(0);

/// Total supervised background-task re-runs so far in this process.
pub fn worker_respawns() -> u64 {
    WORKER_RESPAWNS.load(Ordering::Relaxed)
}

/// [`spawn_background`] with a bounded respawn budget: if the task
/// panics, it is re-run up to `retries` more times with a short linear
/// backoff (10 ms, 20 ms, ...) between attempts.  The task must be
/// re-runnable (`Fn`, not `FnOnce`) — refresh builds qualify because
/// each is a pure function of its captured inputs writing into an
/// idempotent completion slot.  Every panic still counts in
/// [`worker_panics`]; each re-run counts in [`worker_respawns`].  A task
/// that exhausts the budget is abandoned, which downstream consumers
/// already tolerate (the prefetch slot never fills and the synchronous
/// bit-identical fallback runs instead).
pub fn spawn_background_retry(retries: u32, task: impl Fn() + Send + Sync + 'static) {
    ensure_pool(global().threads());
    rayon::spawn(move || {
        for attempt in 0..=retries {
            crate::util::fault::maybe_stall("slow_worker");
            let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(&task));
            if caught.is_ok() {
                return;
            }
            WORKER_PANICS.fetch_add(1, Ordering::Relaxed);
            if attempt < retries {
                WORKER_RESPAWNS.fetch_add(1, Ordering::Relaxed);
                std::thread::sleep(std::time::Duration::from_millis(10 * (attempt as u64 + 1)));
            }
        }
    });
}

/// The process-wide default; resolves (and caches) [`Parallelism::auto`]
/// on first use if nothing was set.
pub fn global() -> Parallelism {
    if let Some(p) = *GLOBAL.read().unwrap() {
        return p;
    }
    let auto = Parallelism::auto();
    let mut w = GLOBAL.write().unwrap();
    *w.get_or_insert(auto)
}

// ---------------------------------------------------------------------
// per-thread scratch arena
// ---------------------------------------------------------------------

#[derive(Default)]
struct Pools {
    f32s: Vec<Vec<f32>>,
    u32s: Vec<Vec<u32>>,
    usizes: Vec<Vec<usize>>,
}

thread_local! {
    static POOLS: RefCell<Pools> = RefCell::new(Pools::default());
}

/// Keep at most this many spare buffers per type per thread.
const POOL_CAP: usize = 8;

static ARENA_REUSED: AtomicU64 = AtomicU64::new(0);
static ARENA_FRESH: AtomicU64 = AtomicU64::new(0);

/// (buffers reused, buffers freshly allocated) since process start or the
/// last [`reset_arena_stats`].  Reuse should dominate in steady state.
pub fn arena_stats() -> (u64, u64) {
    (
        ARENA_REUSED.load(Ordering::Relaxed),
        ARENA_FRESH.load(Ordering::Relaxed),
    )
}

pub fn reset_arena_stats() {
    ARENA_REUSED.store(0, Ordering::Relaxed);
    ARENA_FRESH.store(0, Ordering::Relaxed);
}

macro_rules! arena_fn {
    ($name:ident, $field:ident, $ty:ty, $zero:expr) => {
        /// Run `f` with a zeroed scratch buffer of `len` elements drawn
        /// from (and returned to) the calling thread's pool.
        pub fn $name<R>(len: usize, f: impl FnOnce(&mut [$ty]) -> R) -> R {
            let recycled = POOLS.with(|p| p.borrow_mut().$field.pop());
            let mut buf = match recycled {
                Some(b) => {
                    ARENA_REUSED.fetch_add(1, Ordering::Relaxed);
                    b
                }
                None => {
                    ARENA_FRESH.fetch_add(1, Ordering::Relaxed);
                    Vec::new()
                }
            };
            buf.clear();
            buf.resize(len, $zero);
            let out = f(&mut buf);
            POOLS.with(|p| {
                let mut p = p.borrow_mut();
                if p.$field.len() < POOL_CAP {
                    p.$field.push(buf);
                }
            });
            out
        }
    };
}

arena_fn!(with_f32, f32s, f32, 0.0);
arena_fn!(with_u32, u32s, u32, 0);
arena_fn!(with_usize, usizes, usize, 0);

// ---------------------------------------------------------------------
// slicing helper
// ---------------------------------------------------------------------

/// Split `s` into consecutive mutable chunks of the given sizes (which
/// must sum to at most `s.len()`); used to hand each parallel worker a
/// disjoint, variable-width output region.  Takes any size iterator so
/// hot-path callers need not materialize a `Vec` first (the `*_into`
/// kernels' zero-allocation contract, rule R04).
pub fn split_varsize<'a, T, I>(mut s: &'a mut [T], sizes: I) -> Vec<&'a mut [T]>
where
    I: IntoIterator<Item = usize>,
{
    let sizes = sizes.into_iter();
    let mut out = Vec::with_capacity(sizes.size_hint().0);
    for n in sizes {
        let (head, tail) = s.split_at_mut(n);
        out.push(head);
        s = tail;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sequential_never_parallelizes() {
        let p = Parallelism::sequential();
        assert!(!p.is_parallel());
        assert!(!p.should_parallelize(usize::MAX));
    }

    #[test]
    fn grain_gates_small_work() {
        let p = Parallelism::with_threads(4);
        assert!(!p.should_parallelize(p.grain() - 1));
        assert!(p.should_parallelize(p.grain()));
        let tiny = p.with_grain(1);
        assert!(tiny.should_parallelize(1));
    }

    #[test]
    fn chunk_rows_covers_everything() {
        let p = Parallelism::with_threads(3);
        for rows in [0usize, 1, 7, 100, 1001] {
            let c = p.chunk_rows(rows);
            assert!(c >= 1);
            assert!(c * (rows.div_ceil(c.max(1)).max(1)) >= rows);
        }
    }

    #[test]
    fn arena_reuses_buffers() {
        // snapshot deltas: the counters are process-global and other
        // tests run concurrently, but they only ever increment
        let (reused0, _) = arena_stats();
        with_f32(128, |b| {
            assert_eq!(b.len(), 128);
            assert!(b.iter().all(|&x| x == 0.0));
            b[0] = 5.0;
        });
        // second draw on this thread must come from the pool, zeroed again
        with_f32(64, |b| {
            assert_eq!(b.len(), 64);
            assert!(b.iter().all(|&x| x == 0.0));
        });
        let (reused1, fresh1) = arena_stats();
        assert!(
            reused1 > reused0,
            "expected a pool hit, got ({reused1}, {fresh1})"
        );
    }

    #[test]
    fn nested_arena_draws_are_distinct() {
        with_u32(8, |a| {
            a[0] = 1;
            with_u32(8, |b| {
                b[0] = 2;
                assert_eq!(a[0], 1);
            });
            assert_eq!(a[0], 1);
        });
    }

    #[test]
    fn split_varsize_partitions() {
        let mut v: Vec<u32> = (0..10).collect();
        let parts = split_varsize(&mut v, [3, 0, 4, 3]);
        assert_eq!(parts.len(), 4);
        assert_eq!(parts[0], &[0, 1, 2]);
        assert_eq!(parts[1], &[] as &[u32]);
        assert_eq!(parts[2], &[3, 4, 5, 6]);
        assert_eq!(parts[3], &[7, 8, 9]);
    }

    #[test]
    fn auto_detects_at_least_one_thread() {
        assert!(Parallelism::auto().threads() >= 1);
        assert!(global().threads() >= 1);
    }

    #[test]
    fn supervised_respawn_reruns_a_panicking_task() {
        use std::sync::Arc;
        let respawns0 = worker_respawns();
        let attempts = Arc::new(AtomicU64::new(0));
        let done = Arc::new(AtomicU64::new(0));
        let (a, d) = (attempts.clone(), done.clone());
        spawn_background_retry(2, move || {
            if a.fetch_add(1, Ordering::SeqCst) == 0 {
                panic!("first attempt fails (injected by the test)");
            }
            d.store(1, Ordering::SeqCst);
        });
        for _ in 0..2000 {
            if done.load(Ordering::SeqCst) == 1 {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(2));
        }
        assert_eq!(done.load(Ordering::SeqCst), 1, "task finished after respawn");
        assert_eq!(attempts.load(Ordering::SeqCst), 2);
        assert!(worker_respawns() > respawns0);
    }
}
