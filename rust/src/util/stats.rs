//! Summary statistics + fixed-width table printing (the reporting half of
//! the criterion replacement; the measurement half is `bench::harness`).

/// Mean of a slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Sample standard deviation.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// p-th percentile (linear interpolation), p in [0, 100].  NaN samples
/// order deterministically (`total_cmp`: positive NaNs above +inf,
/// negative NaNs below -inf) instead of panicking the sort.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let mut s = xs.to_vec();
    s.sort_by(f64::total_cmp);
    let rank = p / 100.0 * (s.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        s[lo]
    } else {
        s[lo] + (rank - lo as f64) * (s[hi] - s[lo])
    }
}

pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// "mean±std" with sensible digits.
pub fn fmt_mean_std(xs: &[f64]) -> String {
    format!("{:.2}±{:.2}", mean(xs), std_dev(xs))
}

/// Fixed-width ASCII table writer used by every bench target so the
/// regenerated tables read like the paper's.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new<S: Into<String>>(headers: Vec<S>) -> Self {
        Table {
            headers: headers.into_iter().map(Into::into).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row<S: Into<String>>(&mut self, cells: Vec<S>) {
        let cells: Vec<String> = cells.into_iter().map(Into::into).collect();
        assert_eq!(cells.len(), self.headers.len(), "row width mismatch");
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut width = vec![0usize; ncol];
        for (i, h) in self.headers.iter().enumerate() {
            width[i] = h.chars().count();
        }
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                width[i] = width[i].max(c.chars().count());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], out: &mut String| {
            for (i, c) in cells.iter().enumerate() {
                out.push_str("| ");
                out.push_str(c);
                for _ in c.chars().count()..width[i] {
                    out.push(' ');
                }
                out.push(' ');
            }
            out.push_str("|\n");
        };
        fmt_row(&self.headers, &mut out);
        for (i, w) in width.iter().enumerate() {
            out.push_str(if i == 0 { "|" } else { "|" });
            for _ in 0..w + 2 {
                out.push('-');
            }
        }
        out.push_str("|\n");
        for row in &self.rows {
            fmt_row(row, &mut out);
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_stats() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((mean(&xs) - 2.5).abs() < 1e-12);
        assert!((std_dev(&xs) - 1.2909944487358056).abs() < 1e-12);
        assert!((median(&xs) - 2.5).abs() < 1e-12);
        assert!((percentile(&xs, 0.0) - 1.0).abs() < 1e-12);
        assert!((percentile(&xs, 100.0) - 4.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_with_nan_samples_does_not_panic() {
        // regression: partial_cmp().unwrap() panicked on NaN input
        let xs = [1.0, f64::NAN, 2.0];
        assert!((percentile(&xs, 0.0) - 1.0).abs() < 1e-12);
        assert!((percentile(&xs, 50.0) - 2.0).abs() < 1e-12);
        assert!(percentile(&xs, 100.0).is_nan());
        assert!((median(&xs) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn table_renders() {
        let mut t = Table::new(vec!["model", "acc"]);
        t.row(vec!["GCN", "95.33"]);
        t.row(vec!["GraphSAGE", "96.61"]);
        let s = t.render();
        assert!(s.contains("| GCN       |"));
        assert!(s.lines().count() == 4);
    }

    #[test]
    #[should_panic]
    fn table_rejects_bad_width() {
        let mut t = Table::new(vec!["a", "b"]);
        t.row(vec!["only-one"]);
    }
}
