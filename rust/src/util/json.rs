//! Minimal JSON parser + writer (serde replacement for the offline image).
//!
//! Supports the full JSON grammar we exchange with `python/compile/aot.py`
//! (objects, arrays, strings with escapes, numbers, bools, null).  Numbers
//! are kept as f64; integer accessors check for exactness.

use anyhow::{anyhow, bail, Result};
use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            bail!("trailing garbage at byte {}", p.i);
        }
        Ok(v)
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => Err(anyhow!("expected object, got {self:?}")),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(a) => Ok(a),
            _ => Err(anyhow!("expected array, got {self:?}")),
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => Err(anyhow!("expected string, got {self:?}")),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => Err(anyhow!("expected number, got {self:?}")),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let f = self.as_f64()?;
        if f < 0.0 || f.fract() != 0.0 || f > u64::MAX as f64 {
            bail!("expected non-negative integer, got {f}");
        }
        Ok(f as usize)
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => Err(anyhow!("expected bool, got {self:?}")),
        }
    }

    /// Field lookup on an object.
    pub fn get(&self, key: &str) -> Result<&Json> {
        self.as_obj()?
            .get(key)
            .ok_or_else(|| anyhow!("missing key {key:?}"))
    }

    /// Optional field lookup.
    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Serialize (compact).
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Convenience constructors for building JSON output (bench reports etc.).
impl From<f64> for Json {
    fn from(v: f64) -> Self {
        Json::Num(v)
    }
}
impl From<usize> for Json {
    fn from(v: usize) -> Self {
        Json::Num(v as f64)
    }
}
impl From<&str> for Json {
    fn from(v: &str) -> Self {
        Json::Str(v.to_string())
    }
}
impl From<bool> for Json {
    fn from(v: bool) -> Self {
        Json::Bool(v)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Self {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

/// Build an object from pairs.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected {:?} at byte {}", c as char, self.i);
        }
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.i)
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected ',' or '}}', got {:?} at {}", c as char, self.i),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            self.ws();
            a.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(a));
                }
                c => bail!("expected ',' or ']', got {:?} at {}", c as char, self.i),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            let hex = self
                                .b
                                .get(self.i..self.i + 4)
                                .ok_or_else(|| anyhow!("bad \\u escape"))?;
                            let cp = u32::from_str_radix(std::str::from_utf8(hex)?, 16)?;
                            self.i += 4;
                            // Surrogate pairs: only BMP needed for our data,
                            // but handle pairs for completeness.
                            let ch = if (0xD800..0xDC00).contains(&cp) {
                                if self.b.get(self.i) == Some(&b'\\')
                                    && self.b.get(self.i + 1) == Some(&b'u')
                                {
                                    let hex2 = self
                                        .b
                                        .get(self.i + 2..self.i + 6)
                                        .ok_or_else(|| anyhow!("bad surrogate"))?;
                                    let lo =
                                        u32::from_str_radix(std::str::from_utf8(hex2)?, 16)?;
                                    self.i += 6;
                                    0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00)
                                } else {
                                    bail!("lone high surrogate");
                                }
                            } else {
                                cp
                            };
                            s.push(
                                char::from_u32(ch)
                                    .ok_or_else(|| anyhow!("bad codepoint {ch:#x}"))?,
                            );
                        }
                        e => bail!("bad escape \\{:?}", e as char),
                    }
                }
                c if c < 0x80 => s.push(c as char),
                _ => {
                    // multi-byte UTF-8: copy raw bytes
                    let start = self.i - 1;
                    let len = utf8_len(c);
                    let chunk = self
                        .b
                        .get(start..start + len)
                        .ok_or_else(|| anyhow!("truncated utf8"))?;
                    s.push_str(std::str::from_utf8(chunk)?);
                    self.i = start + len;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(text.parse::<f64>().map_err(|e| {
            anyhow!("bad number {text:?} at byte {start}: {e}")
        })?))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": false}], "c": "x\ny"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x\ny");
        let a = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(a[2].get("b").unwrap().as_bool().unwrap(), false);
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"ops":[{"name":"spmm","shape":[100,64],"meta":{"cap":1024}}],"x":-0.25}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn escapes_roundtrip() {
        let v = Json::Str("a\"b\\c\nd\té—e".to_string());
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(
            Json::parse(r#""é😀""#).unwrap(),
            Json::Str("é😀".into())
        );
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("nul").is_err());
    }

    #[test]
    fn usize_strictness() {
        assert_eq!(Json::parse("7").unwrap().as_usize().unwrap(), 7);
        assert!(Json::parse("7.5").unwrap().as_usize().is_err());
        assert!(Json::parse("-1").unwrap().as_usize().is_err());
    }
}
