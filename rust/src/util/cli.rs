//! Tiny CLI argument parser (clap replacement).
//!
//! Supports `--key value`, `--key=value`, boolean `--flag`, and positional
//! arguments.  Typed accessors with defaults; unknown-flag detection via
//! [`Args::finish`].
//!
//! Value-vs-positional disambiguation: a bare `--key` greedily consumes
//! the next token as its value (so `--lr -0.01` works — a single leading
//! `-` is a legal value), which would swallow a positional after a
//! boolean flag (`--verbose train` used to record `verbose="train"` and
//! lose the subcommand).  Callers therefore declare their boolean flags
//! ([`Args::parse_with_bools`]): a declared flag never consumes the next
//! token, and `--flag=false` remains available for explicit values.

use anyhow::{anyhow, bail, Result};
use std::collections::BTreeMap;

#[derive(Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    flags: BTreeMap<String, String>,
    consumed: std::cell::RefCell<Vec<String>>,
}

impl Args {
    pub fn parse_env() -> Args {
        Self::parse(std::env::args().skip(1))
    }

    /// [`Args::parse_env`] with a declared boolean-flag set.
    pub fn parse_env_with_bools(bools: &[&str]) -> Args {
        Self::parse_with_bools(std::env::args().skip(1), bools)
    }

    pub fn parse<I: IntoIterator<Item = S>, S: Into<String>>(items: I) -> Args {
        Self::parse_with_bools(items, &[])
    }

    /// Parse with `bools` declared as boolean flags: `--verbose train`
    /// keeps `train` positional instead of treating it as the flag's
    /// value, while an explicit boolean literal is still consumed
    /// (`--rsc false` keeps meaning rsc = false).  Undeclared flags keep
    /// the greedy behavior (required for negative numeric values like
    /// `--lr -0.01`).
    pub fn parse_with_bools<I: IntoIterator<Item = S>, S: Into<String>>(
        items: I,
        bools: &[&str],
    ) -> Args {
        let is_bool_literal = |s: &str| {
            matches!(s, "true" | "1" | "yes" | "on" | "false" | "0" | "no" | "off")
        };
        let mut a = Args::default();
        let mut it = items.into_iter().map(Into::into).peekable();
        while let Some(tok) = it.next() {
            if let Some(body) = tok.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    a.flags.insert(k.to_string(), v.to_string());
                } else if bools.contains(&body)
                    && !it.peek().map(|n| is_bool_literal(n.as_str())).unwrap_or(false)
                {
                    a.flags.insert(body.to_string(), "true".to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    a.flags.insert(body.to_string(), v);
                } else {
                    a.flags.insert(body.to_string(), "true".to_string());
                }
            } else {
                a.positional.push(tok);
            }
        }
        a
    }

    fn mark(&self, key: &str) {
        self.consumed.borrow_mut().push(key.to_string());
    }

    pub fn has(&self, key: &str) -> bool {
        self.mark(key);
        self.flags.contains_key(key)
    }

    pub fn str_opt(&self, key: &str) -> Option<String> {
        self.mark(key);
        self.flags.get(key).cloned()
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.str_opt(key).unwrap_or_else(|| default.to_string())
    }

    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize> {
        match self.str_opt(key) {
            None => Ok(default),
            Some(s) => s
                .parse()
                .map_err(|e| anyhow!("--{key}: bad integer {s:?}: {e}")),
        }
    }

    pub fn u64_or(&self, key: &str, default: u64) -> Result<u64> {
        match self.str_opt(key) {
            None => Ok(default),
            Some(s) => s
                .parse()
                .map_err(|e| anyhow!("--{key}: bad integer {s:?}: {e}")),
        }
    }

    pub fn f64_or(&self, key: &str, default: f64) -> Result<f64> {
        match self.str_opt(key) {
            None => Ok(default),
            Some(s) => s
                .parse()
                .map_err(|e| anyhow!("--{key}: bad float {s:?}: {e}")),
        }
    }

    pub fn bool_or(&self, key: &str, default: bool) -> Result<bool> {
        match self.str_opt(key) {
            None => Ok(default),
            Some(s) => match s.as_str() {
                "true" | "1" | "yes" | "on" => Ok(true),
                "false" | "0" | "no" | "off" => Ok(false),
                _ => bail!("--{key}: bad bool {s:?}"),
            },
        }
    }

    /// Comma-separated list.
    pub fn list_or(&self, key: &str, default: &str) -> Vec<String> {
        self.str_or(key, default)
            .split(',')
            .filter(|s| !s.is_empty())
            .map(|s| s.to_string())
            .collect()
    }

    /// Error on any flag never queried (catches typos).
    pub fn finish(&self) -> Result<()> {
        let seen = self.consumed.borrow();
        for k in self.flags.keys() {
            if !seen.contains(k) {
                bail!("unknown flag --{k}");
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_forms() {
        let a = Args::parse(["train", "--epochs", "50", "--budget=0.1", "--cache"]);
        assert_eq!(a.positional, vec!["train"]);
        assert_eq!(a.usize_or("epochs", 1).unwrap(), 50);
        assert_eq!(a.f64_or("budget", 1.0).unwrap(), 0.1);
        assert!(a.bool_or("cache", false).unwrap());
        assert_eq!(a.usize_or("missing", 9).unwrap(), 9);
        a.finish().unwrap();
    }

    #[test]
    fn unknown_flag_detected() {
        let a = Args::parse(["--oops", "1"]);
        assert!(a.finish().is_err());
    }

    #[test]
    fn list_parsing() {
        let a = Args::parse(["--datasets", "a,b,c"]);
        assert_eq!(a.list_or("datasets", ""), vec!["a", "b", "c"]);
    }

    #[test]
    fn bad_values_error() {
        let a = Args::parse(["--n", "xyz"]);
        assert!(a.usize_or("n", 0).is_err());
    }

    #[test]
    fn declared_bool_flag_keeps_following_positional() {
        // regression: `--verbose train` used to record verbose="train"
        // and lose the subcommand entirely
        let a = Args::parse_with_bools(["--verbose", "train"], &["verbose"]);
        assert_eq!(a.positional, vec!["train"]);
        assert!(a.bool_or("verbose", false).unwrap());
        a.finish().unwrap();
        // same shape mid-command-line
        let a = Args::parse_with_bools(
            ["train", "--rsc", "--epochs", "50"],
            &["rsc", "verbose"],
        );
        assert_eq!(a.positional, vec!["train"]);
        assert!(a.bool_or("rsc", false).unwrap());
        assert_eq!(a.usize_or("epochs", 1).unwrap(), 50);
    }

    #[test]
    fn declared_bool_flag_still_accepts_eq_values() {
        let a = Args::parse_with_bools(["--verbose=false", "train"], &["verbose"]);
        assert_eq!(a.positional, vec!["train"]);
        assert!(!a.bool_or("verbose", true).unwrap());
    }

    #[test]
    fn declared_bool_flag_still_consumes_explicit_literals() {
        // `--rsc false` predates the bool-flag declaration and must keep
        // meaning rsc = false, not rsc = true + stray positional
        let a = Args::parse_with_bools(["train", "--rsc", "false"], &["rsc"]);
        assert_eq!(a.positional, vec!["train"]);
        assert!(!a.bool_or("rsc", true).unwrap());
        let a = Args::parse_with_bools(["--verbose", "0", "train"], &["verbose"]);
        assert_eq!(a.positional, vec!["train"]);
        assert!(!a.bool_or("verbose", true).unwrap());
    }

    #[test]
    fn negative_values_still_parse_for_value_flags() {
        let a = Args::parse_with_bools(["train", "--lr", "-0.01"], &["verbose"]);
        assert_eq!(a.positional, vec!["train"]);
        assert_eq!(a.f64_or("lr", 0.0).unwrap(), -0.01);
    }
}
