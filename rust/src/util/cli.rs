//! Tiny CLI argument parser (clap replacement).
//!
//! Supports `--key value`, `--key=value`, boolean `--flag`, and positional
//! arguments.  Typed accessors with defaults; unknown-flag detection via
//! [`Args::finish`].

use anyhow::{anyhow, bail, Result};
use std::collections::BTreeMap;

#[derive(Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    flags: BTreeMap<String, String>,
    consumed: std::cell::RefCell<Vec<String>>,
}

impl Args {
    pub fn parse_env() -> Args {
        Self::parse(std::env::args().skip(1))
    }

    pub fn parse<I: IntoIterator<Item = S>, S: Into<String>>(items: I) -> Args {
        let mut a = Args::default();
        let mut it = items.into_iter().map(Into::into).peekable();
        while let Some(tok) = it.next() {
            if let Some(body) = tok.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    a.flags.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    a.flags.insert(body.to_string(), v);
                } else {
                    a.flags.insert(body.to_string(), "true".to_string());
                }
            } else {
                a.positional.push(tok);
            }
        }
        a
    }

    fn mark(&self, key: &str) {
        self.consumed.borrow_mut().push(key.to_string());
    }

    pub fn has(&self, key: &str) -> bool {
        self.mark(key);
        self.flags.contains_key(key)
    }

    pub fn str_opt(&self, key: &str) -> Option<String> {
        self.mark(key);
        self.flags.get(key).cloned()
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.str_opt(key).unwrap_or_else(|| default.to_string())
    }

    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize> {
        match self.str_opt(key) {
            None => Ok(default),
            Some(s) => s
                .parse()
                .map_err(|e| anyhow!("--{key}: bad integer {s:?}: {e}")),
        }
    }

    pub fn u64_or(&self, key: &str, default: u64) -> Result<u64> {
        match self.str_opt(key) {
            None => Ok(default),
            Some(s) => s
                .parse()
                .map_err(|e| anyhow!("--{key}: bad integer {s:?}: {e}")),
        }
    }

    pub fn f64_or(&self, key: &str, default: f64) -> Result<f64> {
        match self.str_opt(key) {
            None => Ok(default),
            Some(s) => s
                .parse()
                .map_err(|e| anyhow!("--{key}: bad float {s:?}: {e}")),
        }
    }

    pub fn bool_or(&self, key: &str, default: bool) -> Result<bool> {
        match self.str_opt(key) {
            None => Ok(default),
            Some(s) => match s.as_str() {
                "true" | "1" | "yes" | "on" => Ok(true),
                "false" | "0" | "no" | "off" => Ok(false),
                _ => bail!("--{key}: bad bool {s:?}"),
            },
        }
    }

    /// Comma-separated list.
    pub fn list_or(&self, key: &str, default: &str) -> Vec<String> {
        self.str_or(key, default)
            .split(',')
            .filter(|s| !s.is_empty())
            .map(|s| s.to_string())
            .collect()
    }

    /// Error on any flag never queried (catches typos).
    pub fn finish(&self) -> Result<()> {
        let seen = self.consumed.borrow();
        for k in self.flags.keys() {
            if !seen.contains(k) {
                bail!("unknown flag --{k}");
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_forms() {
        let a = Args::parse(["train", "--epochs", "50", "--budget=0.1", "--cache"]);
        assert_eq!(a.positional, vec!["train"]);
        assert_eq!(a.usize_or("epochs", 1).unwrap(), 50);
        assert_eq!(a.f64_or("budget", 1.0).unwrap(), 0.1);
        assert!(a.bool_or("cache", false).unwrap());
        assert_eq!(a.usize_or("missing", 9).unwrap(), 9);
        a.finish().unwrap();
    }

    #[test]
    fn unknown_flag_detected() {
        let a = Args::parse(["--oops", "1"]);
        assert!(a.finish().is_err());
    }

    #[test]
    fn list_parsing() {
        let a = Args::parse(["--datasets", "a,b,c"]);
        assert_eq!(a.list_or("datasets", ""), vec!["a", "b", "c"]);
    }

    #[test]
    fn bad_values_error() {
        let a = Args::parse(["--n", "xyz"]);
        assert!(a.usize_or("n", 0).is_err());
    }
}
