//! Minimal property-based-testing harness (proptest replacement).
//!
//! A property is a closure over a seeded [`Rng`]; [`check`] runs it for N
//! seeds and, on failure, reports the failing seed so the case replays
//! deterministically (`check_seed`).  No shrinking — generators are kept
//! small instead.

use crate::util::rng::Rng;

pub const DEFAULT_CASES: u64 = 64;

/// Run `prop` for `cases` generated inputs; panics with the failing seed.
pub fn check<F: FnMut(&mut Rng)>(name: &str, cases: u64, mut prop: F) {
    for case in 0..cases {
        let seed = 0xC0FFEE ^ (case.wrapping_mul(0x9E3779B97F4A7C15));
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut rng = Rng::new(seed);
            prop(&mut rng);
        }));
        if let Err(payload) = result {
            let msg = payload
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".to_string());
            panic!(
                "property {name:?} failed at case {case} (seed {seed:#x}): {msg}\n\
                 replay with prop::check_seed({seed:#x}, ...)"
            );
        }
    }
}

/// Replay one case.
pub fn check_seed<F: FnOnce(&mut Rng)>(seed: u64, prop: F) {
    let mut rng = Rng::new(seed);
    prop(&mut rng);
}

/// Generator helpers.
pub fn vec_f32(rng: &mut Rng, len: usize, scale: f32) -> Vec<f32> {
    (0..len).map(|_| rng.normal_f32() * scale).collect()
}

pub fn assert_close(a: &[f32], b: &[f32], tol: f32, what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length mismatch");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        let denom = 1.0_f32.max(x.abs()).max(y.abs());
        assert!(
            (x - y).abs() / denom <= tol,
            "{what}: mismatch at {i}: {x} vs {y} (tol {tol})"
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property() {
        check("add-commutes", 32, |rng| {
            let a = rng.f64();
            let b = rng.f64();
            assert_eq!(a + b, b + a);
        });
    }

    #[test]
    #[should_panic(expected = "property")]
    fn failing_property_reports_seed() {
        check("always-false", 4, |_rng| {
            panic!("boom");
        });
    }

    #[test]
    fn close_assertion() {
        assert_close(&[1.0, 2.0], &[1.0 + 1e-7, 2.0], 1e-5, "t");
    }

    #[test]
    #[should_panic(expected = "mismatch at 0")]
    fn close_assertion_fails() {
        assert_close(&[1.0], &[2.0], 1e-5, "t");
    }
}
