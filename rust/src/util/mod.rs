//! Foundational substrates built in-repo (the offline image carries no
//! serde/clap/criterion/proptest/rand, so we implement what we need):
//! JSON, RNG, CLI parsing, statistics, a tiny property-test harness,
//! wall-clock timers, and the thread-parallelism substrate (rayon-backed
//! config + per-thread scratch arena) the hot-path kernels share.

pub mod cli;
pub mod counters;
pub mod fault;
pub mod health;
pub mod json;
pub mod parallel;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod timer;
