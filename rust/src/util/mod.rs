//! Foundational substrates built in-repo (the offline image carries no
//! serde/clap/criterion/proptest/rand, so we implement what we need):
//! JSON, RNG, CLI parsing, statistics, a tiny property-test harness and
//! wall-clock timers.

pub mod cli;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod timer;
