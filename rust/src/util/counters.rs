//! The process-global manifest: every `static Atomic*`/`OnceLock` in the
//! crate is registered here, with its reset discipline spelled out.  The
//! lint pass (rule R06, see DESIGN.md §Static analysis) cross-checks this
//! file against the tree in both directions — an unregistered global and a
//! stale registry entry are both violations — so the list below is
//! machine-verified complete.
//!
//! Why a manifest: tests and harnesses that observe process-global counters
//! (kernel-variant tallies, plan-cache hits, autotune stats) are only
//! deterministic if they know every global that can move underneath them
//! and can reset the resettable ones from a single hook
//! ([`reset_process_globals`]).  The `seed_determinism` suite's
//! single-`#[test]`-per-file constraint exists for exactly this reason;
//! the manifest makes the full inventory visible instead of folklore.

/// How a registered global behaves across a reset boundary.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ResetKind {
    /// Observability tally; zeroed by its reset hook.
    Counter,
    /// Cached derived state; cleared (re-derivable) by its reset hook.
    Cache,
    /// Behaviour switch; restored to its default by its reset hook.
    Toggle,
    /// Initialized once per process, immutable afterwards; never reset.
    InitOnce,
    /// Monotonic by contract; must NEVER be reset (correctness, not
    /// observability, depends on it).
    Monotonic,
}

/// One registered process global.
#[derive(Debug, Clone, Copy)]
pub struct GlobalEntry {
    /// Crate-relative module path of the `static` (for humans and for the
    /// R06 cross-check, which matches on the trailing identifier).
    pub path: &'static str,
    pub kind: ResetKind,
    /// Why the global exists and what resetting it means.
    pub doc: &'static str,
    /// Reset hook; `None` for [`ResetKind::InitOnce`] and
    /// [`ResetKind::Monotonic`] entries.
    pub reset: Option<fn()>,
}

impl GlobalEntry {
    /// The bare `static` identifier (last path segment).
    pub fn name(&self) -> &'static str {
        self.path.rsplit("::").next().unwrap_or(self.path).trim()
    }
}

/// Restore SIMD dispatch to its default (enabled; hardware still gates it).
fn reset_simd_switch() {
    crate::runtime::simd::set_enabled(true);
}

macro_rules! global {
    ($($seg:ident)::+, $kind:ident, $doc:literal) => {
        GlobalEntry {
            path: stringify!($($seg)::+),
            kind: ResetKind::$kind,
            doc: $doc,
            reset: None,
        }
    };
    ($($seg:ident)::+, $kind:ident, $doc:literal, $reset:expr) => {
        GlobalEntry {
            path: stringify!($($seg)::+),
            kind: ResetKind::$kind,
            doc: $doc,
            reset: Some($reset),
        }
    };
}

/// Every process global in the crate.  Keep entries grouped by module; the
/// R06 pass flags any `static Atomic*`/`OnceLock` missing from this list
/// and any entry whose static no longer exists.
pub const REGISTERED: &[GlobalEntry] = &[
    global!(
        runtime::plan::PLAN_BUILDS,
        Counter,
        "SpMM plans built since process start (plan-cache miss tally)",
        crate::runtime::plan::reset_plan_stats
    ),
    global!(
        runtime::plan::PLAN_HITS,
        Counter,
        "SpMM plan-cache hits since process start",
        crate::runtime::plan::reset_plan_stats
    ),
    global!(
        runtime::native::KERNEL_SCALAR,
        Counter,
        "planned-SpMM executions taking the scalar kernel variant",
        crate::runtime::native::reset_spmm_kernel_stats
    ),
    global!(
        runtime::native::KERNEL_AXPY4,
        Counter,
        "planned-SpMM executions taking the 4-wide unrolled variant",
        crate::runtime::native::reset_spmm_kernel_stats
    ),
    global!(
        runtime::native::KERNEL_SIMD,
        Counter,
        "planned-SpMM executions taking the SIMD tiled variant",
        crate::runtime::native::reset_spmm_kernel_stats
    ),
    global!(
        runtime::autotune::TUNE_RACES,
        Counter,
        "autotune invocations that lost a first-measurement race",
        crate::runtime::autotune::reset_autotune_stats
    ),
    global!(
        runtime::autotune::TUNE_CACHE_HITS,
        Counter,
        "autotune invocations answered from the process tuning cache",
        crate::runtime::autotune::reset_autotune_stats
    ),
    global!(
        runtime::autotune::TUNE_FALLBACKS,
        Counter,
        "autotune invocations that fell back to the static heuristic",
        crate::runtime::autotune::reset_autotune_stats
    ),
    global!(
        runtime::autotune::CACHE,
        Cache,
        "process-wide tuning cache: measured kernel choice per plan shape",
        crate::runtime::autotune::reset_tuning_cache
    ),
    global!(
        runtime::simd::DISABLED,
        Toggle,
        "the --no-simd ablation switch; reset restores SIMD dispatch",
        reset_simd_switch
    ),
    global!(
        runtime::simd::AVX,
        InitOnce,
        "cached hardware AVX probe; immutable for the process lifetime"
    ),
    global!(
        coordinator::shard::SHARD_MERGES,
        Counter,
        "merged per-shard selections built by the sharded engine",
        crate::coordinator::shard::reset_shard_stats
    ),
    global!(
        coordinator::shard::SHARD_MERGE_EDGES,
        Counter,
        "edges concatenated across shard replicas into merged selections",
        crate::coordinator::shard::reset_shard_stats
    ),
    global!(
        coordinator::shard::SHARD_DISAGREEMENTS,
        Counter,
        "defensive exact-fallbacks when shard replicas' plan decisions split",
        crate::coordinator::shard::reset_shard_stats
    ),
    global!(
        sampling::selection::TAG_COUNTER,
        Monotonic,
        "immutability-tag allocator; reset would alias tags and poison buffer caches"
    ),
    global!(
        util::parallel::POOL,
        InitOnce,
        "rayon pool size chosen at first use; immutable for the process"
    ),
    global!(
        util::parallel::WORKER_PANICS,
        Monotonic,
        "worker panics since process start; monotonic tally, survives resets"
    ),
    global!(
        util::parallel::WORKER_RESPAWNS,
        Monotonic,
        "supervised background-task re-runs after a panic; monotonic tally"
    ),
    global!(
        util::parallel::ARENA_REUSED,
        Counter,
        "scratch-arena buffers served from the per-thread free list",
        crate::util::parallel::reset_arena_stats
    ),
    global!(
        util::parallel::ARENA_FRESH,
        Counter,
        "scratch-arena buffers freshly allocated",
        crate::util::parallel::reset_arena_stats
    ),
];

/// Run every registered reset hook.  Idempotent (hooks shared by several
/// entries, e.g. the plan-stat pair, just run more than once); globals
/// whose kind is [`ResetKind::InitOnce`] or [`ResetKind::Monotonic`] are
/// left untouched by design.
pub fn reset_process_globals() {
    for entry in REGISTERED {
        if let Some(reset) = entry.reset {
            reset();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::BTreeSet;

    /// Structural invariants only — no hook is invoked here, so this test
    /// cannot race sibling tests that observe the live globals.
    #[test]
    fn manifest_is_well_formed() {
        assert!(!REGISTERED.is_empty());
        let names: BTreeSet<&str> = REGISTERED.iter().map(|e| e.name()).collect();
        assert_eq!(
            names.len(),
            REGISTERED.len(),
            "static identifiers must be unique for the R06 name match"
        );
        for entry in REGISTERED {
            assert!(!entry.doc.is_empty(), "{} needs a doc line", entry.path);
            match entry.kind {
                ResetKind::Counter | ResetKind::Cache | ResetKind::Toggle => {
                    assert!(
                        entry.reset.is_some(),
                        "{} is resettable but has no hook",
                        entry.path
                    );
                }
                ResetKind::InitOnce | ResetKind::Monotonic => {
                    assert!(
                        entry.reset.is_none(),
                        "{} must not carry a reset hook",
                        entry.path
                    );
                }
            }
        }
    }
}
