//! Synthetic stand-ins for Reddit / Yelp / ogbn-proteins / ogbn-products
//! (see DESIGN.md Substitutions).  Scale is reduced to CPU size, but the
//! *shape-relevant* properties are preserved:
//!
//! * cluster structure (=> low-rank adjacency, Appendix A.1);
//! * heavy-tailed degrees (=> pair selection determines FLOPs, Fig. 3);
//! * task type and label rate per dataset (multi-class accuracy for
//!   Reddit/products, multi-label F1 for Yelp, binary-ish AUC for
//!   proteins, 8% label rate for products).
//!
//! Dimensions here must stay in sync with `python/compile/model.py::
//! DATASETS` — the runtime cross-checks against the artifact manifest.

use crate::data::dataset::{Dataset, DatasetCfg, Labels, Split};
use crate::graph::{generate_power_law, generate_sbm, PowerLawConfig, SbmConfig};
use crate::util::rng::Rng;
use anyhow::{anyhow, ensure, Result};

pub const ALL_DATASETS: [&str; 5] =
    ["tiny", "reddit-sim", "yelp-sim", "proteins-sim", "products-sim"];

/// Config table — mirrors model.py DATASETS (dims) + generation knobs.
pub fn dataset_cfg(name: &str) -> Result<DatasetCfg> {
    let base = |name: &str,
                v: usize,
                e: usize,
                d_in: usize,
                d_h: usize,
                n_class: usize,
                multilabel: bool,
                saint_v: usize,
                saint_m: usize,
                train_frac: f64|
     -> DatasetCfg {
        DatasetCfg {
            name: name.to_string(),
            v,
            e,
            d_in,
            d_h,
            n_class,
            multilabel,
            layers: 3,
            gcnii_layers: 4,
            gcnii_alpha: 0.1,
            gcnii_lambda: 0.5,
            appnp_layers: 8,
            appnp_alpha: 0.1,
            gin_eps: 0.0,
            saint_v,
            saint_m,
            clusters: if multilabel { 10 } else { n_class },
            p_intra: 0.85,
            skew: 0.8,
            train_frac,
            feature_strength: 1.5,
            label_noise: 0.05,
        }
    };
    Ok(match name {
        // label rates follow Table 6: 65.86%, 75%, 65%, 8.03%
        "reddit-sim" => base("reddit-sim", 6000, 150_000, 64, 64, 16, false, 1536, 24576, 0.6586),
        "yelp-sim" => base("yelp-sim", 8000, 80_000, 64, 64, 20, true, 2048, 16384, 0.75),
        "proteins-sim" => base("proteins-sim", 4000, 200_000, 32, 64, 8, true, 0, 0, 0.65),
        "products-sim" => base("products-sim", 20000, 400_000, 64, 64, 16, false, 4096, 49152, 0.0803),
        "tiny" => base("tiny", 128, 1024, 16, 16, 4, false, 64, 256, 0.6),
        _ => {
            return Err(anyhow!(
                "unknown dataset {name:?} (expected one of: {})",
                ALL_DATASETS.join("|")
            ))
        }
    })
}

/// Generate the dataset deterministically from (name, seed).
pub fn load_or_generate(name: &str, seed: u64) -> Result<Dataset> {
    let cfg = dataset_cfg(name)?;
    let mut rng = Rng::new(seed ^ 0xD5EA5E);
    let sbm = generate_sbm(&SbmConfig {
        v: cfg.v,
        e_directed: cfg.e,
        clusters: cfg.clusters,
        p_intra: cfg.p_intra,
        skew: cfg.skew,
        seed: rng.next_u64(),
    });

    // Cluster centroids in feature space.
    let mut centroids = vec![0f32; cfg.clusters * cfg.d_in];
    rng.fill_normal_f32(&mut centroids, 0.0, 1.0);

    let mut features = vec![0f32; cfg.v * cfg.d_in];
    for v in 0..cfg.v {
        let c = sbm.cluster[v];
        for j in 0..cfg.d_in {
            features[v * cfg.d_in + j] = cfg.feature_strength
                * centroids[c * cfg.d_in + j]
                + rng.normal_f32();
        }
    }

    let labels = if cfg.multilabel {
        // Each class is a random halfspace over centroid space: labels are
        // cluster-correlated but not cluster-identical (Yelp/proteins style).
        let mut w = vec![0f32; cfg.n_class * cfg.d_in];
        rng.fill_normal_f32(&mut w, 0.0, 1.0);
        let mut lab = vec![0f32; cfg.v * cfg.n_class];
        for v in 0..cfg.v {
            let c = sbm.cluster[v];
            for k in 0..cfg.n_class {
                let mut dot = 0f32;
                for j in 0..cfg.d_in {
                    dot += w[k * cfg.d_in + j] * centroids[c * cfg.d_in + j];
                }
                let noisy = dot + 0.5 * rng.normal_f32();
                lab[v * cfg.n_class + k] = if noisy > 0.0 { 1.0 } else { 0.0 };
            }
        }
        Labels::MultiLabel(lab)
    } else {
        let mut lab = Vec::with_capacity(cfg.v);
        for v in 0..cfg.v {
            let y = if rng.chance(cfg.label_noise) {
                rng.below(cfg.n_class) as i32
            } else {
                (sbm.cluster[v] % cfg.n_class) as i32
            };
            lab.push(y);
        }
        Labels::MultiClass(lab)
    };

    // Splits: train_frac / half-rest val / rest test, random by node.
    let mut order: Vec<usize> = (0..cfg.v).collect();
    rng.shuffle(&mut order);
    let n_train = (cfg.train_frac * cfg.v as f64).round() as usize;
    let n_val = (cfg.v - n_train) / 2;
    let mut split = vec![Split::Test; cfg.v];
    for (i, &v) in order.iter().enumerate() {
        split[v] = if i < n_train {
            Split::Train
        } else if i < n_train + n_val {
            Split::Val
        } else {
            Split::Test
        };
    }

    let ds = Dataset {
        cfg,
        adj: sbm.adj,
        features,
        labels,
        split,
        cluster: sbm.cluster,
    };
    ds.validate()?;
    Ok(ds)
}

/// Synthetic power-law dataset at arbitrary scale — the `shard_scale`
/// bench's 10M-node input (DESIGN.md §Sharded execution).  Unlike the
/// fixed-size table above, every dimension is a parameter, and the
/// graph comes from the *streaming* generator
/// ([`crate::graph::generate_power_law`]): two deterministic RNG passes
/// straight into CSR, so peak memory is the final footprint, never a
/// second triple-list copy.  Every scale-sensitive product is
/// checked-multiplied so a mis-typed `--nodes` fails with a clear error
/// instead of wrapping at >= 10M nodes.
///
/// Features/labels are deliberately narrow (caller picks `d`): the
/// bench measures sharded sparse backward throughput, not accuracy.
pub fn scale_free(v: usize, avg_degree: usize, d: usize, n_class: usize, seed: u64) -> Result<Dataset> {
    ensure!(v >= 16, "scale-free dataset needs >= 16 nodes, got {v}");
    ensure!(avg_degree >= 1, "avg_degree must be >= 1");
    ensure!(d >= 1 && n_class >= 2, "need d >= 1 and n_class >= 2");
    let e_draws = v
        .checked_mul(avg_degree)
        .and_then(|x| x.checked_mul(2))
        .ok_or_else(|| anyhow!("v={v} x avg_degree={avg_degree} overflows the edge count"))?;
    let feat_len = v
        .checked_mul(d)
        .ok_or_else(|| anyhow!("v={v} x d={d} overflows the feature buffer"))?;

    let mut rng = Rng::new(seed ^ 0x5CA1E);
    let g = generate_power_law(&PowerLawConfig {
        v,
        e_directed: e_draws,
        skew: 0.8,
        seed: rng.next_u64(),
    })?;
    let e = g.adj.nnz(); // dedup makes this <= e_draws; cfg records the real count

    let mut features = vec![0f32; feat_len];
    rng.fill_normal_f32(&mut features, 0.0, 1.0);
    let labels = Labels::MultiClass((0..v).map(|_| rng.below(n_class) as i32).collect());
    // fixed 1/8 train, 1/8 val stride split: O(1) memory beyond the
    // vector itself (a shuffled permutation would add 8 bytes/node)
    let split = (0..v)
        .map(|i| match i % 8 {
            0 => Split::Train,
            1 => Split::Val,
            _ => Split::Test,
        })
        .collect();

    let ds = Dataset {
        cfg: DatasetCfg {
            name: format!("scale-free-{v}"),
            v,
            e,
            d_in: d,
            d_h: d,
            n_class,
            multilabel: false,
            layers: 3,
            gcnii_layers: 4,
            gcnii_alpha: 0.1,
            gcnii_lambda: 0.5,
            appnp_layers: 8,
            appnp_alpha: 0.1,
            gin_eps: 0.0,
            saint_v: 0,
            saint_m: 0,
            clusters: n_class,
            p_intra: 0.0,
            skew: 0.8,
            train_frac: 0.125,
            feature_strength: 0.0,
            label_noise: 1.0,
        },
        adj: g.adj,
        features,
        labels,
        split,
        cluster: vec![0usize; v],
    };
    ds.validate()?;
    Ok(ds)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tiny_generates_and_validates() {
        let ds = load_or_generate("tiny", 1).unwrap();
        assert_eq!(ds.cfg.v, 128);
        assert_eq!(ds.adj.nnz(), 1024);
        assert_eq!(ds.count(Split::Train), 77); // 0.6*128 rounded
    }

    #[test]
    fn deterministic() {
        let a = load_or_generate("tiny", 7).unwrap();
        let b = load_or_generate("tiny", 7).unwrap();
        assert_eq!(a.features, b.features);
        assert_eq!(a.adj, b.adj);
        let c = load_or_generate("tiny", 8).unwrap();
        assert_ne!(a.features, c.features);
    }

    #[test]
    fn features_are_cluster_separable() {
        // mean intra-cluster feature distance < inter-cluster distance
        let ds = load_or_generate("tiny", 3).unwrap();
        let d_in = ds.cfg.d_in;
        let dist = |a: usize, b: usize| -> f32 {
            (0..d_in)
                .map(|j| {
                    let d = ds.features[a * d_in + j] - ds.features[b * d_in + j];
                    d * d
                })
                .sum::<f32>()
        };
        let mut rng = Rng::new(5);
        let (mut intra, mut inter) = (0f64, 0f64);
        let (mut ni, mut nx) = (0, 0);
        for _ in 0..2000 {
            let a = rng.below(ds.cfg.v);
            let b = rng.below(ds.cfg.v);
            if a == b {
                continue;
            }
            if ds.cluster[a] == ds.cluster[b] {
                intra += dist(a, b) as f64;
                ni += 1;
            } else {
                inter += dist(a, b) as f64;
                nx += 1;
            }
        }
        assert!(intra / ni as f64 * 1.3 < inter / nx as f64);
    }

    #[test]
    fn all_configs_resolve() {
        for name in ALL_DATASETS {
            let c = dataset_cfg(name).unwrap();
            assert!(c.e % 2 == 0);
            assert!(c.v > 0);
        }
        assert!(dataset_cfg("nope").is_err());
    }

    #[test]
    fn scale_free_generates_and_validates() {
        let ds = scale_free(50_000, 4, 8, 4, 11).unwrap();
        assert_eq!(ds.cfg.v, 50_000);
        assert_eq!(ds.adj.nnz(), ds.cfg.e);
        assert!(ds.cfg.e > 0 && ds.cfg.e <= 50_000 * 8);
        assert!(ds.count(Split::Train) > 0 && ds.count(Split::Val) > 0);
        let again = scale_free(50_000, 4, 8, 4, 11).unwrap();
        assert_eq!(ds.adj, again.adj);
        assert_eq!(ds.features, again.features);
        // overflow guards fire as clean errors, not wraps
        assert!(scale_free(usize::MAX, 2, 8, 4, 0).is_err());
        assert!(scale_free(1 << 40, usize::MAX / 2, 8, 4, 0).is_err());
    }

    /// The satellite's scale witness: a 10M-node power-law graph builds
    /// with peak memory pinned to the closed-form streaming bound —
    /// rowptr + one col array + values — i.e. the triples are never
    /// materialized alongside the CSR (that alone would add 12 bytes x
    /// nnz, blowing the asserted ceiling).
    #[test]
    fn ten_million_node_graph_builds_with_bounded_peak_memory() {
        let cfg = crate::graph::PowerLawConfig {
            v: 10_000_000,
            e_directed: 2_000_000,
            skew: 0.8,
            seed: 42,
        };
        let g = generate_power_law(&cfg).unwrap();
        assert_eq!(g.adj.n, 10_000_000);
        assert!(g.adj.nnz() > 1_000_000, "nnz {} lost too much to dedup", g.adj.nnz());
        let bound = cfg.peak_bound_bytes().unwrap();
        assert!(
            g.peak_alloc_bytes <= bound,
            "peak {} exceeds the streaming bound {bound}",
            g.peak_alloc_bytes
        );
        // sanity: the bound itself is ~one CSR, not a multiple of it
        let csr_bytes = (g.adj.n + 1) * std::mem::size_of::<usize>() + g.adj.nnz() * 8;
        assert!(bound < csr_bytes + cfg.e_directed * 8);
    }

    #[test]
    fn multilabel_dataset() {
        let mut cfg_names = vec![];
        for n in ALL_DATASETS {
            if dataset_cfg(n).unwrap().multilabel {
                cfg_names.push(n);
            }
        }
        assert_eq!(cfg_names, vec!["yelp-sim", "proteins-sim"]);
    }
}
