//! Dataset substrate: synthetic stand-ins for the paper's four benchmarks
//! plus the GraphSAINT random-walk subgraph sampler.

pub mod dataset;
pub mod saint;
pub mod synth;

pub use dataset::{Dataset, DatasetCfg, Labels, Split};
pub use saint::{SaintSampler, Subgraph};
pub use synth::{dataset_cfg, load_or_generate, scale_free, ALL_DATASETS};
