//! Core dataset representation shared by the trainer, the coordinator and
//! every bench target.

use crate::graph::{Csr, Permutation, ReorderKind};
use anyhow::{ensure, Result};

/// Mirrors `python/compile/model.py::DatasetCfg`; the runtime asserts the
/// manifest's echo of these dims matches at artifact-load time.
#[derive(Debug, Clone, PartialEq)]
pub struct DatasetCfg {
    pub name: String,
    pub v: usize,
    pub e: usize, // directed edges WITHOUT self-loops
    pub d_in: usize,
    pub d_h: usize,
    pub n_class: usize,
    pub multilabel: bool,
    pub layers: usize,
    pub gcnii_layers: usize,
    pub gcnii_alpha: f32,
    pub gcnii_lambda: f32,
    /// APPNP power-iteration depth K (every step is an RSC site).
    pub appnp_layers: usize,
    /// APPNP teleport probability alpha.
    pub appnp_alpha: f32,
    /// GIN epsilon (self-term weight `1 + eps` in the sum matrix).
    pub gin_eps: f32,
    pub saint_v: usize,
    pub saint_m: usize,
    // generation parameters (rust-side only)
    pub clusters: usize,
    pub p_intra: f64,
    pub skew: f64,
    pub train_frac: f64,
    pub feature_strength: f32,
    pub label_noise: f64,
}

impl DatasetCfg {
    /// Edge count including self-loops — the `m` every full-batch
    /// executable is compiled for.
    pub fn m(&self) -> usize {
        self.e + self.v
    }
}

#[derive(Debug, Clone)]
pub enum Labels {
    /// One class id per node.
    MultiClass(Vec<i32>),
    /// Dense V×C {0,1} matrix, row-major.
    MultiLabel(Vec<f32>),
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Split {
    Train,
    Val,
    Test,
}

#[derive(Debug, Clone)]
pub struct Dataset {
    pub cfg: DatasetCfg,
    /// Raw symmetric adjacency, no self-loops, unit weights.
    pub adj: Csr,
    /// V × d_in, row-major.
    pub features: Vec<f32>,
    pub labels: Labels,
    /// Split assignment per node.
    pub split: Vec<Split>,
    /// Ground-truth cluster per node (diagnostics only).
    pub cluster: Vec<usize>,
}

impl Dataset {
    pub fn mask(&self, which: Split) -> Vec<f32> {
        self.split
            .iter()
            .map(|&s| if s == which { 1.0 } else { 0.0 })
            .collect()
    }

    pub fn count(&self, which: Split) -> usize {
        self.split.iter().filter(|&&s| s == which).count()
    }

    pub fn labels_i32(&self) -> Result<&[i32]> {
        match &self.labels {
            Labels::MultiClass(l) => Ok(l),
            _ => anyhow::bail!("dataset {} is multilabel", self.cfg.name),
        }
    }

    pub fn labels_f32(&self) -> Result<&[f32]> {
        match &self.labels {
            Labels::MultiLabel(l) => Ok(l),
            _ => anyhow::bail!("dataset {} is multiclass", self.cfg.name),
        }
    }

    /// The dataset relabeled into a locality-friendly node order (the
    /// one-shot reordering pass of the vectorized locality layer — see
    /// `graph/reorder.rs`): adjacency, features, labels, split masks and
    /// cluster ids all move through the same [`Permutation`], so training
    /// in the returned dataset is exactly training on the original graph
    /// with renamed nodes.  The permutation is returned so callers can
    /// inverse-permute predictions back to original node order at eval.
    pub fn reordered(&self, kind: ReorderKind) -> (Dataset, Permutation) {
        let perm = Permutation::for_graph(kind, &self.adj);
        let labels = match &self.labels {
            Labels::MultiClass(l) => Labels::MultiClass(perm.gather(l)),
            Labels::MultiLabel(l) => {
                Labels::MultiLabel(perm.apply_rows_f32(l, self.cfg.n_class))
            }
        };
        let ds = Dataset {
            cfg: self.cfg.clone(),
            adj: self.adj.permute(&perm),
            features: perm.apply_rows_f32(&self.features, self.cfg.d_in),
            labels,
            split: perm.gather(&self.split),
            cluster: perm.gather(&self.cluster),
        };
        debug_assert!(ds.validate().is_ok());
        (ds, perm)
    }

    /// Structural sanity used by tests and at load time.
    pub fn validate(&self) -> Result<()> {
        let c = &self.cfg;
        ensure!(self.adj.n == c.v, "adjacency size mismatch");
        ensure!(self.adj.nnz() == c.e, "edge count mismatch");
        ensure!(self.features.len() == c.v * c.d_in, "feature shape");
        ensure!(self.split.len() == c.v, "split len");
        // non-finite inputs would poison every downstream SpMM, trip the
        // divergence watchdog on step 0 and defeat its exact-retry (the
        // exact path is just as poisoned) — reject them at load time
        if let Some(i) = self.features.iter().position(|x| !x.is_finite()) {
            anyhow::bail!(
                "feature {i} (node {}, dim {}) is non-finite: {}",
                i / c.d_in,
                i % c.d_in,
                self.features[i]
            );
        }
        if let Some(i) = self.adj.val.iter().position(|x| !x.is_finite()) {
            anyhow::bail!("adjacency value {i} is non-finite: {}", self.adj.val[i]);
        }
        match &self.labels {
            Labels::MultiClass(l) => {
                ensure!(!c.multilabel, "label kind mismatch");
                ensure!(l.len() == c.v, "labels len");
                ensure!(
                    l.iter().all(|&x| (0..c.n_class as i32).contains(&x)),
                    "label out of range"
                );
            }
            Labels::MultiLabel(l) => {
                ensure!(c.multilabel, "label kind mismatch");
                ensure!(l.len() == c.v * c.n_class, "labels shape");
                ensure!(
                    l.iter().all(|&x| x == 0.0 || x == 1.0),
                    "labels not binary"
                );
            }
        }
        Ok(())
    }
}
