//! GraphSAINT random-walk subgraph sampler (Zeng et al., 2020), simplified:
//! we sample root nodes from the train split, run fixed-length random
//! walks, induce the subgraph on the visited set, and train full-batch on
//! the (padded) subgraph.  Per the paper's footnote 1, all subgraphs are
//! pre-sampled offline; the RSC caching mechanism is then applied *per
//! sampled subgraph*.
//!
//! Subgraphs are padded to the AOT shapes (saint_v nodes, saint_m edges):
//! ghost nodes have zero features and zero mask, ghost edges zero weight.

use crate::data::dataset::{Dataset, Labels, Split};
use crate::graph::Csr;
use crate::util::rng::Rng;

/// An induced, padded subgraph ready for the `saint_*` executables.
#[derive(Debug, Clone)]
pub struct Subgraph {
    /// Global node id per local slot (only the first `n_real` are real).
    pub nodes: Vec<u32>,
    pub n_real: usize,
    /// Induced adjacency on local ids (unpadded; nnz <= m_cap).
    pub adj: Csr,
    /// Padded node capacity (== cfg.saint_v) and edge capacity (saint_m).
    pub v_cap: usize,
    pub m_cap: usize,
}

pub struct SaintSampler {
    pub roots: usize,
    pub walk_len: usize,
}

impl SaintSampler {
    /// Defaults scaled from Table 10 (8000 roots / walk length 4 at 233k
    /// nodes, proportionally reduced here).
    pub fn for_dataset(ds: &Dataset) -> SaintSampler {
        let roots = (ds.cfg.saint_v / 4).max(8);
        SaintSampler { roots, walk_len: 3 }
    }

    /// Sample one subgraph.  The visited set is truncated to v_cap nodes
    /// and the induced edges to m_cap (deterministic order, highest-degree
    /// roots first are *not* prioritized — uniform truncation).
    pub fn sample(&self, ds: &Dataset, rng: &mut Rng) -> Subgraph {
        let v_cap = ds.cfg.saint_v;
        let m_cap = ds.cfg.saint_m;
        assert!(v_cap > 0, "dataset {} has no SAINT config", ds.cfg.name);
        let train_nodes: Vec<u32> = (0..ds.cfg.v)
            .filter(|&v| ds.split[v] == Split::Train)
            .map(|v| v as u32)
            .collect();

        let mut visited: Vec<u32> = Vec::with_capacity(v_cap);
        let mut in_set = vec![false; ds.cfg.v];
        let push = |v: u32, visited: &mut Vec<u32>, in_set: &mut Vec<bool>| {
            if visited.len() < v_cap && !in_set[v as usize] {
                in_set[v as usize] = true;
                visited.push(v);
            }
        };
        'outer: for _ in 0..self.roots {
            let mut cur = train_nodes[rng.below(train_nodes.len())];
            push(cur, &mut visited, &mut in_set);
            for _ in 0..self.walk_len {
                let (nbrs, _) = ds.adj.row(cur as usize);
                if nbrs.is_empty() {
                    break;
                }
                cur = nbrs[rng.below(nbrs.len())];
                push(cur, &mut visited, &mut in_set);
                if visited.len() >= v_cap {
                    break 'outer;
                }
            }
        }

        // local id map
        let mut local = vec![u32::MAX; ds.cfg.v];
        for (i, &v) in visited.iter().enumerate() {
            local[v as usize] = i as u32;
        }
        // induced edges, truncated to m_cap
        let mut triples = Vec::new();
        'edges: for (i, &v) in visited.iter().enumerate() {
            let (nbrs, ws) = ds.adj.row(v as usize);
            for (&u, &w) in nbrs.iter().zip(ws) {
                let lu = local[u as usize];
                if lu != u32::MAX {
                    triples.push((i as u32, lu, w));
                    if triples.len() >= m_cap.saturating_sub(v_cap) {
                        break 'edges; // leave room for self-loops
                    }
                }
            }
        }
        let n_real = visited.len();
        let adj = Csr::from_triples(n_real.max(1), triples);
        Subgraph {
            nodes: visited,
            n_real,
            adj,
            v_cap,
            m_cap,
        }
    }
}

impl Subgraph {
    /// Padded features [v_cap × d_in], zero rows for ghosts.
    pub fn features(&self, ds: &Dataset) -> Vec<f32> {
        let d = ds.cfg.d_in;
        let mut x = vec![0f32; self.v_cap * d];
        for (i, &v) in self.nodes.iter().enumerate() {
            x[i * d..(i + 1) * d]
                .copy_from_slice(&ds.features[v as usize * d..(v as usize + 1) * d]);
        }
        x
    }

    /// Padded train mask (ghosts and non-train nodes are 0).
    pub fn train_mask(&self, ds: &Dataset) -> Vec<f32> {
        let mut m = vec![0f32; self.v_cap];
        for (i, &v) in self.nodes.iter().enumerate() {
            if ds.split[v as usize] == Split::Train {
                m[i] = 1.0;
            }
        }
        m
    }

    /// Padded labels.
    pub fn labels_i32(&self, ds: &Dataset) -> Vec<i32> {
        let mut l = vec![0i32; self.v_cap];
        if let Labels::MultiClass(src) = &ds.labels {
            for (i, &v) in self.nodes.iter().enumerate() {
                l[i] = src[v as usize];
            }
        }
        l
    }

    pub fn labels_f32(&self, ds: &Dataset) -> Vec<f32> {
        let c = ds.cfg.n_class;
        let mut l = vec![0f32; self.v_cap * c];
        if let Labels::MultiLabel(src) = &ds.labels {
            for (i, &v) in self.nodes.iter().enumerate() {
                l[i * c..(i + 1) * c]
                    .copy_from_slice(&src[v as usize * c..(v as usize + 1) * c]);
            }
        }
        l
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::load_or_generate;

    #[test]
    fn sample_respects_caps() {
        let ds = load_or_generate("tiny", 2).unwrap();
        let sampler = SaintSampler::for_dataset(&ds);
        let mut rng = Rng::new(9);
        for _ in 0..5 {
            let sg = sampler.sample(&ds, &mut rng);
            assert!(sg.n_real <= ds.cfg.saint_v);
            assert!(sg.adj.nnz() + sg.n_real <= ds.cfg.saint_m);
            assert!(sg.adj.validate());
            // all nodes distinct
            let mut ns = sg.nodes.clone();
            ns.sort_unstable();
            ns.dedup();
            assert_eq!(ns.len(), sg.n_real);
        }
    }

    #[test]
    fn induced_edges_exist_in_parent() {
        let ds = load_or_generate("tiny", 3).unwrap();
        let sampler = SaintSampler { roots: 10, walk_len: 3 };
        let mut rng = Rng::new(1);
        let sg = sampler.sample(&ds, &mut rng);
        let dense = ds.adj.to_dense();
        for r in 0..sg.adj.n {
            let (cs, _) = sg.adj.row(r);
            for &c in cs {
                let gv = sg.nodes[r] as usize;
                let gu = sg.nodes[c as usize] as usize;
                assert!(dense[gv][gu] > 0.0, "edge not in parent graph");
            }
        }
    }

    #[test]
    fn features_padded_with_zeros() {
        let ds = load_or_generate("tiny", 4).unwrap();
        let sampler = SaintSampler { roots: 2, walk_len: 1 };
        let mut rng = Rng::new(2);
        let sg = sampler.sample(&ds, &mut rng);
        let x = sg.features(&ds);
        assert_eq!(x.len(), sg.v_cap * ds.cfg.d_in);
        // ghost rows all zero
        for i in sg.n_real..sg.v_cap {
            for j in 0..ds.cfg.d_in {
                assert_eq!(x[i * ds.cfg.d_in + j], 0.0);
            }
        }
        // mask zero on ghosts
        let m = sg.train_mask(&ds);
        assert!(m[sg.n_real..].iter().all(|&v| v == 0.0));
    }
}
