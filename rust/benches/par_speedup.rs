//! Sequential vs parallel native runtime: per-op wall-clock for the
//! sparse hot kernels (SpMM, dense matmuls, row norms, CSR transpose,
//! Figure 5 slicing, top-k argsort) on the paper's synthetic graphs —
//! plus the planned-SpMM comparisons (per-call grouping vs cached plan,
//! and the scalar/axpy4/SIMD-tiled kernel variants).
//!
//! Shapes to hold: on the largest graph (products-sim, |V|=20k, |E|=400k)
//! with >= 4 worker threads the SpMM/MatMul rows should clear 2x, and the
//! SIMD-tiled planned-SpMM variant should clear 1.5x over axpy4 at
//! d >= 64 single-threaded.  Every comparison here is between bitwise-
//! identical computations (DESIGN.md §Parallel runtime, §Vectorized
//! locality layer), so the speedups are "free" accuracy-wise.
//!
//! Thread count: RSC_THREADS env var, else auto-detected.
//! `-- --smoke` runs a seconds-scale subset (the CI bench smoke).

use rsc::bench::harness::{header, BenchScale};
use rsc::bench::support::{
    native_seq_vs_par, planned_vs_unplanned, prefetch_on_vs_off, spmm_variant_rows,
    GraphFixture,
};
use rsc::util::parallel::Parallelism;
use rsc::util::stats::Table;

fn main() -> anyhow::Result<()> {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let par = Parallelism::auto();
    header(
        "par_speedup",
        &format!(
            "native kernels, sequential vs {} worker threads{}",
            par.threads(),
            if smoke { " [smoke]" } else { "" }
        ),
    );
    if !par.is_parallel() {
        println!("only one core available: parallel path == sequential path");
    }
    let scale = BenchScale::from_env(1, 0);
    let iters = if smoke {
        3
    } else if scale.full {
        30
    } else {
        10
    };
    let datasets: &[&str] = if smoke {
        &["tiny"]
    } else {
        &["reddit-sim", "products-sim"]
    };
    // one graph synthesis per dataset, shared by every section below
    let fixtures: Vec<GraphFixture> = datasets
        .iter()
        .map(|d| GraphFixture::gcn(d))
        .collect::<anyhow::Result<_>>()?;

    let mut t = Table::new(vec!["dataset", "op", "seq ms", "par ms", "speedup"]);
    for fx in &fixtures {
        for r in native_seq_vs_par(fx, iters, par)? {
            t.row(vec![
                fx.name.clone(),
                r.op.clone(),
                format!("{:.3}", r.seq_ms),
                format!("{:.3}", r.par_ms),
                format!("{:.2}x", r.speedup()),
            ]);
        }
    }
    t.print();
    println!(
        "target: >=2x on products-sim SpMM/MatMul with >=4 threads \
         (identical outputs; RSC's sampling speedups in table2 stack on top)"
    );

    header(
        "par_speedup/plan",
        "SpMM with per-call grouping vs a cached SpmmPlan (bitwise-equal outputs)",
    );
    let mut tp = Table::new(vec![
        "dataset",
        "nnz",
        "unplanned ms",
        "planned ms",
        "speedup",
        "plan build ms",
        "break-even steps",
    ]);
    for fx in &fixtures {
        let r = planned_vs_unplanned(fx, iters, par)?;
        tp.row(vec![
            fx.name.clone(),
            r.nnz.to_string(),
            format!("{:.3}", r.unplanned_ms),
            format!("{:.3}", r.planned_ms),
            format!("{:.2}x", r.speedup()),
            format!("{:.3}", r.build_ms),
            format!("{:.1}", r.breakeven_steps()),
        ]);
    }
    tp.print();
    println!(
        "the plan is built once per sample-cache refresh (epoch-wise), not per \
         step: cached epochs pay the planned column only"
    );

    header(
        "par_speedup/kernels",
        "planned-SpMM kernel variants, single thread (bitwise-equal outputs)",
    );
    let widths: &[usize] = if smoke { &[16, 64] } else { &[16, 64, 128, 256] };
    let mut tk = Table::new(vec![
        "dataset",
        "d",
        "tile",
        "scalar ms",
        "axpy4 ms",
        "simd ms",
        "simd vs axpy4",
        "simd vs scalar",
    ]);
    for fx in &fixtures {
        for r in spmm_variant_rows(fx, widths, iters) {
            tk.row(vec![
                fx.name.clone(),
                r.d.to_string(),
                r.tile.to_string(),
                format!("{:.3}", r.scalar_ms),
                format!("{:.3}", r.axpy4_ms),
                format!("{:.3}", r.simd_ms),
                format!("{:.2}x", r.simd_vs_axpy4()),
                format!("{:.2}x", r.simd_vs_scalar()),
            ]);
        }
    }
    tk.print();
    println!(
        "acceptance shape: simd-tiled >= 1.5x over axpy4 at d >= 64, single \
         thread, on the synthetic power-law graphs (requires AVX; on non-AVX \
         hosts the simd column degenerates to the scalar mirror)"
    );

    if smoke {
        println!("\n[smoke] skipping the prefetch end-to-end section");
        return Ok(());
    }
    header(
        "par_speedup/prefetch",
        "sample-cache refreshes: inline (--no-prefetch) vs background-prefetched \
         (bitwise-equal results)",
    );
    let mut tf = Table::new(vec![
        "dataset",
        "hot sample ms (sync)",
        "hot sample ms (prefetch)",
        "bg build ms",
        "prefetch hit rate",
    ]);
    for dataset in ["reddit-sim", "products-sim"] {
        let r = prefetch_on_vs_off(dataset, if scale.full { 60 } else { 20 })?;
        tf.row(vec![
            dataset.to_string(),
            format!("{:.3}", r.sample_ms_off),
            format!("{:.3}", r.sample_ms_on),
            format!("{:.3}", r.bg_build_ms),
            format!("{:.0}%", 100.0 * r.pf.hit_rate()),
        ]);
    }
    tf.print();
    println!(
        "with prefetching the refresh build (scores, top-k, Figure 5 slicing, \
         plan construction) runs on spare workers: the hot path pays only the \
         swap-in, so its sampling column collapses toward zero"
    );
    Ok(())
}
