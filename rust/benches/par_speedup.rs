//! Sequential vs parallel native runtime: per-op wall-clock for the
//! sparse hot kernels (SpMM, dense matmuls, row norms, CSR transpose,
//! Figure 5 slicing, top-k argsort) on the paper's synthetic graphs.
//!
//! Shape to hold: on the largest graph (products-sim, |V|=20k, |E|=400k)
//! with >= 4 worker threads the SpMM/MatMul rows should clear 2x.  The
//! parallel results are byte-identical to the sequential ones (DESIGN.md
//! §Parallel runtime), so every speedup here is "free" accuracy-wise.
//!
//! Thread count: RSC_THREADS env var, else auto-detected.

use rsc::bench::harness::{header, BenchScale};
use rsc::bench::support::{native_seq_vs_par, planned_vs_unplanned, prefetch_on_vs_off};
use rsc::util::parallel::Parallelism;
use rsc::util::stats::Table;

fn main() -> anyhow::Result<()> {
    let par = Parallelism::auto();
    header(
        "par_speedup",
        &format!(
            "native kernels, sequential vs {} worker threads",
            par.threads()
        ),
    );
    if !par.is_parallel() {
        println!("only one core available: parallel path == sequential path");
    }
    let scale = BenchScale::from_env(1, 0);
    let iters = if scale.full { 30 } else { 10 };
    let mut t = Table::new(vec!["dataset", "op", "seq ms", "par ms", "speedup"]);
    for dataset in ["reddit-sim", "products-sim"] {
        for r in native_seq_vs_par(dataset, iters, par)? {
            t.row(vec![
                dataset.to_string(),
                r.op.clone(),
                format!("{:.3}", r.seq_ms),
                format!("{:.3}", r.par_ms),
                format!("{:.2}x", r.speedup()),
            ]);
        }
    }
    t.print();
    println!(
        "target: >=2x on products-sim SpMM/MatMul with >=4 threads \
         (identical outputs; RSC's sampling speedups in table2 stack on top)"
    );

    header(
        "par_speedup/plan",
        "SpMM with per-call grouping vs a cached SpmmPlan (bitwise-equal outputs)",
    );
    let mut tp = Table::new(vec![
        "dataset",
        "nnz",
        "unplanned ms",
        "planned ms",
        "speedup",
        "plan build ms",
        "break-even steps",
    ]);
    for dataset in ["reddit-sim", "products-sim"] {
        let r = planned_vs_unplanned(dataset, iters, par)?;
        tp.row(vec![
            dataset.to_string(),
            r.nnz.to_string(),
            format!("{:.3}", r.unplanned_ms),
            format!("{:.3}", r.planned_ms),
            format!("{:.2}x", r.speedup()),
            format!("{:.3}", r.build_ms),
            format!("{:.1}", r.breakeven_steps()),
        ]);
    }
    tp.print();
    println!(
        "the plan is built once per sample-cache refresh (epoch-wise), not per \
         step: cached epochs pay the planned column only"
    );

    header(
        "par_speedup/prefetch",
        "sample-cache refreshes: inline (--no-prefetch) vs background-prefetched \
         (bitwise-equal results)",
    );
    let mut tf = Table::new(vec![
        "dataset",
        "hot sample ms (sync)",
        "hot sample ms (prefetch)",
        "bg build ms",
        "prefetch hit rate",
    ]);
    for dataset in ["reddit-sim", "products-sim"] {
        let r = prefetch_on_vs_off(dataset, if scale.full { 60 } else { 20 })?;
        tf.row(vec![
            dataset.to_string(),
            format!("{:.3}", r.sample_ms_off),
            format!("{:.3}", r.sample_ms_on),
            format!("{:.3}", r.bg_build_ms),
            format!("{:.0}%", 100.0 * r.pf.hit_rate()),
        ]);
    }
    tf.print();
    println!(
        "with prefetching the refresh build (scores, top-k, Figure 5 slicing, \
         plan construction) runs on spare workers: the hot path pays only the \
         swap-in, so its sampling column collapses toward zero"
    );
    Ok(())
}
