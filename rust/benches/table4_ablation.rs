//! Table 4: ablation of the caching and switching mechanisms on
//! proteins-sim (GCN / GraphSAGE / GCNII), all with the greedy allocator.
//!
//! Shape to hold (paper): switching alone improves the metric but costs
//! speed; caching alone boosts speed but hurts the metric (>1%); both
//! together recover the metric at ~0.9x of caching-only speed.

use rsc::bench::harness::{header, BenchScale};
use rsc::bench::support::{run_trials, RunStats};
use rsc::coordinator::RscConfig;
use rsc::model::ops::ModelKind;
use rsc::runtime::XlaBackend;
use rsc::util::stats::Table;

fn main() -> anyhow::Result<()> {
    header("table4", "caching x switching ablation (proteins-sim)");
    let scale = BenchScale::from_env(2, 60);
    let dataset = "proteins-sim";
    let b = XlaBackend::load(dataset)?;
    let mut t = Table::new(vec![
        "model", "caching", "switching", "AUC", "speedup",
    ]);
    for (model, c) in [
        (ModelKind::Gcn, 0.3),
        (ModelKind::Sage, 0.3),
        (ModelKind::Gcnii, 0.5),
    ] {
        let base = run_trials(
            &b,
            dataset,
            model,
            RscConfig::baseline(),
            scale.epochs,
            scale.trials,
        )?;
        let cell = |caching: bool, switching: bool| -> anyhow::Result<RunStats> {
            run_trials(
                &b,
                dataset,
                model,
                RscConfig {
                    budget_c: c,
                    refresh_every: if caching { 10 } else { 1 },
                    switch_frac: if switching { 0.8 } else { 1.0 },
                    ..Default::default()
                },
                scale.epochs,
                scale.trials,
            )
        };
        for (caching, switching) in
            [(false, false), (false, true), (true, false), (true, true)]
        {
            let r = cell(caching, switching)?;
            let row = vec![
                model.name().to_string(),
                if caching { "yes" } else { "no" }.to_string(),
                if switching { "yes" } else { "no" }.to_string(),
                r.metric_pm(),
                format!("{:.2}x", base.wall_mean() / r.wall_mean()),
            ];
            println!("{row:?}");
            t.row(row);
        }
    }
    println!();
    t.print();
    println!("paper (Table 4): caching ~+0.4x speed / -1pt AUC; switching +1pt AUC / -0.05x; both recover");
    Ok(())
}
