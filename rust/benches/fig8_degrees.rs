//! Figure 8: average degree of the nodes picked by top-k sampling across
//! training (reddit-sim, C=0.1).  Shape to hold: the picked-pair degree
//! differs from the graph mean and drifts as the gradient norms evolve —
//! which is exactly why k alone cannot control FLOPs (Fig. 3).

use rsc::bench::harness::{header, BenchScale};
use rsc::bench::support::run_trials;
use rsc::coordinator::RscConfig;
use rsc::data::load_or_generate;
use rsc::model::ops::ModelKind;
use rsc::runtime::XlaBackend;
use rsc::util::stats::{self, Table};

fn main() -> anyhow::Result<()> {
    header("fig8", "mean degree of picked column-row pairs (C=0.1)");
    let scale = BenchScale::from_env(1, 80);
    let dataset = "reddit-sim";
    let b = XlaBackend::load(dataset)?;
    let ds = load_or_generate(dataset, 0)?;
    let graph_mean: f64 = (0..ds.cfg.v).map(|r| ds.adj.row_nnz(r) as f64).sum::<f64>()
        / ds.cfg.v as f64;
    println!("graph mean degree (A, no self-loops): {graph_mean:.1}\n");

    for model in [ModelKind::Gcn, ModelKind::Sage, ModelKind::Gcnii] {
        let rsc = RscConfig { budget_c: 0.1, switch_frac: 1.0, ..Default::default() };
        let r = run_trials(&b, dataset, model, rsc, scale.epochs, 1)?;
        let res = r.last.as_ref().unwrap();
        let sites: Vec<usize> = {
            let mut s: Vec<usize> =
                res.picked_degrees.iter().map(|(l, _, _)| *l).collect();
            s.sort_unstable();
            s.dedup();
            s
        };
        println!("{}:", model.name());
        let mut t = Table::new(vec!["site", "early mean deg", "late mean deg", "overall"]);
        for site in sites {
            let xs: Vec<(u64, f64)> = res
                .picked_degrees
                .iter()
                .filter(|(l, _, _)| *l == site)
                .map(|(_, s, d)| (*s, *d))
                .collect();
            let half = xs.len() / 2;
            let early: Vec<f64> = xs[..half.max(1)].iter().map(|(_, d)| *d).collect();
            let late: Vec<f64> = xs[half..].iter().map(|(_, d)| *d).collect();
            let all: Vec<f64> = xs.iter().map(|(_, d)| *d).collect();
            t.row(vec![
                site.to_string(),
                format!("{:.1}", stats::mean(&early)),
                format!("{:.1}", stats::mean(&late)),
                format!("{:.1}", stats::mean(&all)),
            ]);
        }
        t.print();
    }
    println!("paper (Fig. 8): picked degree != graph mean and evolves with training");
    Ok(())
}
