//! Table 1: where can the approximation go?  The dataset's standard
//! 3-layer GCN on reddit-sim with top-k sampling (k = 0.1|V|) applied in
//! the forward pass, the backward pass, or both.
//!
//! Paper's numbers: none 95.39, fwd-only 16.45 (!), bwd-only 95.25,
//! both 80.74 — the *shape* to reproduce is fwd-only collapsing while
//! bwd-only matches the baseline (Prop 3.1).

use rsc::bench::harness::{header, BenchScale};
use rsc::coordinator::{AllocKind, RscConfig, RscEngine, TrainEngine};
use rsc::data::{load_or_generate, Split};
use rsc::model::ops::{ModelKind, OpNames};
use rsc::model::GraphModel;
use rsc::runtime::{Backend, Value, Workspace, XlaBackend};
use rsc::sampling::{top_k_indices, Selection};
use rsc::train::metrics::MetricKind;
use rsc::train::trainer::full_graph_bufs;
use rsc::util::rng::Rng;
use rsc::util::stats::{self, Table};
use rsc::util::timer::TimeBook;

fn run_variant(
    b: &dyn Backend,
    dataset: &str,
    fwd_approx: bool,
    bwd_approx: bool,
    epochs: usize,
    seed: u64,
) -> anyhow::Result<f64> {
    let ds = load_or_generate(dataset, seed)?;
    let mut rng = Rng::new(seed);
    let bufs = full_graph_bufs(b, &ds, ModelKind::Gcn);
    let mut model = GraphModel::new(ModelKind::Gcn, &ds.cfg, OpNames::full(), &mut rng);
    let x = Value::mat_f32(ds.cfg.v, ds.cfg.d_in, ds.features.clone());
    let labels = Value::vec_i32(ds.labels_i32()?.to_vec());
    let mask = Value::vec_f32(ds.mask(Split::Train));
    let metric = MetricKind::for_dataset(&ds);

    // forward selections: k = 0.1|V| pairs by static column norms
    let k = (0.1 * ds.cfg.v as f64) as usize;
    let fwd_sel: Option<Vec<Selection>> = fwd_approx.then(|| {
        let scores = bufs.matrix.row_norms();
        let rows = top_k_indices(&scores, k);
        // one selection per sparse forward node (= per GCN layer)
        (0..ds.cfg.layers)
            .map(|_| Selection::build(&bufs.matrix, rows.clone(), &bufs.caps))
            .collect()
    });

    // backward approximation: uniform k = 0.1|V|, no caching/switching
    // (Table 1's setting isolates the sampling itself)
    let rsc = RscConfig {
        enabled: bwd_approx,
        budget_c: 0.1,
        allocator: AllocKind::Uniform,
        refresh_every: 1,
        switch_frac: 1.0,
        ..Default::default()
    };
    let widths: Vec<usize> = (0..ModelKind::Gcn.n_spmm_bwd(&ds.cfg))
        .map(|s| ModelKind::Gcn.spmm_width(&ds.cfg, s))
        .collect();
    let mut engine = TrainEngine::Single(RscEngine::new(
        rsc,
        bufs.matrix.clone(),
        bufs.caps.clone(),
        widths,
        epochs as u64,
    )?);
    let mut tb = TimeBook::new();
    let mut ws = Workspace::new();
    let mut best_val = f64::NEG_INFINITY;
    let mut test_at_best = f64::NAN;
    for epoch in 0..epochs {
        model.train_step(
            b,
            &x,
            &labels,
            &mask,
            &bufs,
            &mut engine,
            epoch as u64,
            0.01,
            &mut tb,
            &mut ws,
            fwd_sel.as_deref(),
        )?;
        if epoch % 5 == 0 || epoch + 1 == epochs {
            // evaluation itself is EXACT in every variant
            let logits = model.logits(b, &x, &bufs, &mut tb, &mut ws)?;
            let lf = logits.f32s()?;
            let val = metric.evaluate(&ds, lf, Split::Val);
            if val > best_val {
                best_val = val;
                test_at_best = metric.evaluate(&ds, lf, Split::Test);
            }
        }
    }
    Ok(test_at_best)
}

fn main() -> anyhow::Result<()> {
    header("table1", "approximating SpMM in fwd / bwd / both (GCN, reddit-sim)");
    let scale = BenchScale::from_env(3, 60);
    let b = XlaBackend::load("reddit-sim")?;
    let mut t = Table::new(vec!["method", "accuracy", "paper"]);
    for (name, fwd, bwd, paper) in [
        ("without approximation", false, false, "95.39±0.04"),
        ("only forward", true, false, "16.45±0.39"),
        ("only backward", false, true, "95.25±0.03"),
        ("forward and backward", true, true, "80.74±1.00"),
    ] {
        let accs: Vec<f64> = (0..scale.trials)
            .map(|s| run_variant(&b, "reddit-sim", fwd, bwd, scale.epochs, s as u64))
            .collect::<anyhow::Result<_>>()?;
        let pct: Vec<f64> = accs.iter().map(|a| a * 100.0).collect();
        t.row(vec![
            name.to_string(),
            format!("{:.2}±{:.2}", stats::mean(&pct), stats::std_dev(&pct)),
            paper.to_string(),
        ]);
    }
    t.print();
    println!("shape to hold: fwd-only collapses, bwd-only ~= baseline (Prop 3.1)");
    Ok(())
}
