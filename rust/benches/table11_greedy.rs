//! Table 11: running time of the greedy allocation algorithm — must be
//! negligible next to the training step it dispatches (paper: 20-60ms at
//! 233k-2.4M nodes; proportionally less here).  Also reports the exact-DP
//! solver for the optimality-gap ablation (DESIGN.md).

use rsc::allocator::{evaluate, Allocator, DpExact, GreedyAllocator, LayerScores, UniformAllocator};
use rsc::bench::harness::{bench_fn, header, BenchScale};
use rsc::bench::support::PAPER_DATASETS;
use rsc::data::load_or_generate;
use rsc::sampling::pair_scores;
use rsc::util::rng::Rng;
use rsc::util::stats::Table;

fn layers_for(dataset: &str, sites: usize, rng: &mut Rng) -> anyhow::Result<Vec<LayerScores>> {
    let ds = load_or_generate(dataset, 0)?;
    let matrix = ds.adj.gcn_normalize();
    let col = matrix.row_norms();
    let nnz: Vec<u32> = (0..matrix.n).map(|r| matrix.row_nnz(r) as u32).collect();
    Ok((0..sites)
        .map(|_| {
            let g: Vec<f32> = (0..matrix.n).map(|_| rng.f32()).collect();
            LayerScores { scores: pair_scores(&col, &g), nnz: nnz.clone(), d: ds.cfg.d_h }
        })
        .collect())
}

fn main() -> anyhow::Result<()> {
    header("table11", "greedy allocator runtime (+ DP gap on tiny)");
    let scale = BenchScale::from_env(1, 0);
    let iters = if scale.full { 50 } else { 15 };
    let mut rng = Rng::new(0xA110C);
    let mut t = Table::new(vec!["dataset", "model", "sites", "greedy ms", "uniform ms"]);
    for dataset in PAPER_DATASETS {
        for (model, sites) in [("GCN", 3usize), ("GraphSAGE", 2), ("GCNII", 4)] {
            let layers = layers_for(dataset, sites, &mut rng)?;
            let g = bench_fn("greedy", 1, iters, || {
                GreedyAllocator::default().allocate(&layers, 0.1)
            });
            let u = bench_fn("uniform", 1, iters, || {
                UniformAllocator.allocate(&layers, 0.1)
            });
            t.row(vec![
                dataset.to_string(),
                model.to_string(),
                sites.to_string(),
                format!("{:.2}", g.median_ms),
                format!("{:.4}", u.median_ms),
            ]);
        }
    }
    t.print();
    println!("paper (Table 11): 0.02-0.06s at 233k-2.4M nodes — negligible either way\n");

    // optimality gap vs exact DP (coarse grid so DP stays tractable)
    let layers = layers_for("tiny", 3, &mut rng)?;
    let mut t2 = Table::new(vec!["C", "greedy kept", "dp kept", "gap"]);
    for c in [0.1, 0.3, 0.5] {
        let kg = GreedyAllocator { alpha: 0.05, min_frac: 0.02 }.allocate(&layers, c);
        let kd = DpExact { alpha: 0.05, min_frac: 0.02, ..Default::default() }
            .allocate(&layers, c);
        let (kept_g, _) = evaluate(&layers, &kg);
        let (kept_d, _) = evaluate(&layers, &kd);
        t2.row(vec![
            format!("{c}"),
            format!("{kept_g:.4}"),
            format!("{kept_d:.4}"),
            format!("{:.2}%", 100.0 * (kept_d - kept_g) / kept_d.max(1e-9)),
        ]);
    }
    t2.print();
    Ok(())
}
