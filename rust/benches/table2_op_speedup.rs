//! Table 2: operation-level efficiency — wall-clock of a single backward
//! SpMM / SpMM_MEAN, exact vs RSC-sampled (C=0.1), per dataset.
//!
//! Paper: bwd SpMM speedups 11.6x / 3.49x / 2.89x / 8.98x and SpMM_MEAN
//! 5.92x / 1.75x / 8.26x / 4.43x.  The shape to hold: multi-x per-op
//! speedups that vary with the dataset's degree skew, with the fwd op
//! unchanged.
//!
//! Two sections: the native runtime's sequential-vs-parallel per-op
//! comparison (always runs; the thread-level speedup that *stacks* with
//! RSC's sampling), then the XLA exact-vs-sampled comparison (needs AOT
//! artifacts — skipped with a note when absent or when built without the
//! `xla` feature).

use rsc::allocator::{Allocator, GreedyAllocator, LayerScores};
use rsc::bench::harness::{bench_fn, header, BenchScale};
use rsc::bench::support::{
    native_seq_vs_par, planned_vs_unplanned, GraphFixture, PAPER_DATASETS,
};
use rsc::data::load_or_generate;
use rsc::graph::Csr;
use rsc::runtime::{Backend, Value, XlaBackend};
use rsc::sampling::{pair_scores, top_k_indices, Selection};
use rsc::util::parallel::Parallelism;
use rsc::util::rng::Rng;
use rsc::util::stats::Table;

struct OpRow {
    fwd_ms: f64,
    bwd_exact_ms: f64,
    bwd_rsc_ms: f64,
    cap: usize,
}

fn measure(
    b: &XlaBackend,
    matrix: &Csr,
    caps: &[usize],
    d: usize,
    iters: usize,
    budget_c: f64,
    rng: &mut Rng,
) -> anyhow::Result<OpRow> {
    let v = matrix.n;
    let m = *caps.last().unwrap();
    let g = Value::mat_f32(v, d, (0..v * d).map(|_| rng.normal_f32()).collect());

    // exact backward (= a full-edge SpMM, the same op the fwd pass runs)
    let exact = Selection::exact(matrix, caps);
    let (es, ed, ew) = exact.vals.clone();
    let op = format!("spmm_bwd_nomask_{d}_cap{m}");
    b.run(&op, &[g.clone(), es.clone(), ed.clone(), ew.clone()])?;
    let bwd_exact =
        bench_fn(&op, 1, iters, || {
            b.run(&op, &[g.clone(), es.clone(), ed.clone(), ew.clone()]).unwrap()
        });

    // fwd cost == the same spmm shape (reported for the fwd/bwd split)
    let fwd_ms = bwd_exact.median_ms;

    // RSC: allocate k under C for this single op, sample, pick bucket
    let col = matrix.row_norms();
    let gnorm: Vec<f32> = (0..v).map(|_| rng.f32()).collect();
    let layer = LayerScores {
        scores: pair_scores(&col, &gnorm),
        nnz: (0..v).map(|r| matrix.row_nnz(r) as u32).collect(),
        d,
    };
    let ks = GreedyAllocator::default().allocate(std::slice::from_ref(&layer), budget_c);
    let rows = top_k_indices(&layer.scores, ks[0]);
    let sel = Selection::build(matrix, rows, caps);
    let (ss, sd, sw) = sel.vals.clone();
    let op_s = format!("spmm_bwd_nomask_{d}_cap{}", sel.cap);
    b.run(&op_s, &[g.clone(), ss.clone(), sd.clone(), sw.clone()])?;
    let bwd_rsc = bench_fn(&op_s, 1, iters, || {
        b.run(&op_s, &[g.clone(), ss.clone(), sd.clone(), sw.clone()]).unwrap()
    });

    Ok(OpRow {
        fwd_ms,
        bwd_exact_ms: bwd_exact.median_ms,
        bwd_rsc_ms: bwd_rsc.median_ms,
        cap: sel.cap,
    })
}

fn main() -> anyhow::Result<()> {
    let scale = BenchScale::from_env(1, 0);
    let iters = if scale.full { 50 } else { 15 };

    // -- section 1: native runtime, sequential vs parallel threads ------
    let par = Parallelism::auto();
    header(
        "table2a",
        &format!("native per-op seq vs par ({} threads)", par.threads()),
    );
    // one graph synthesis per dataset, shared by both native sections
    let fixtures: Vec<GraphFixture> = PAPER_DATASETS
        .iter()
        .map(|d| GraphFixture::gcn(d))
        .collect::<anyhow::Result<_>>()?;
    let mut tn = Table::new(vec!["dataset", "op", "seq ms", "par ms", "speedup"]);
    for fx in &fixtures {
        for r in native_seq_vs_par(fx, iters.min(10), par)? {
            tn.row(vec![
                fx.name.clone(),
                r.op.clone(),
                format!("{:.3}", r.seq_ms),
                format!("{:.3}", r.par_ms),
                format!("{:.2}x", r.speedup()),
            ]);
        }
    }
    tn.print();

    // -- section 1b: plan-cached vs per-call-grouped SpMM ---------------
    header(
        "table2a/plan",
        "backward SpMM off a cached SpmmPlan vs per-call grouping",
    );
    let mut tpl = Table::new(vec![
        "dataset",
        "nnz",
        "unplanned ms",
        "planned ms",
        "speedup",
        "plan build ms",
        "break-even steps",
    ]);
    for fx in &fixtures {
        let r = planned_vs_unplanned(fx, iters.min(10), par)?;
        tpl.row(vec![
            fx.name.clone(),
            r.nnz.to_string(),
            format!("{:.3}", r.unplanned_ms),
            format!("{:.3}", r.planned_ms),
            format!("{:.2}x", r.speedup()),
            format!("{:.3}", r.build_ms),
            format!("{:.1}", r.breakeven_steps()),
        ]);
    }
    tpl.print();
    println!(
        "amortization: the plan build appears once per cache refresh (R steps), \
         not per step — cached epochs execute the planned column only"
    );

    // -- section 2: XLA executables, exact vs RSC-sampled bucket --------
    header("table2b", "per-op backward SpMM / SpMM_MEAN speedup at C=0.1");
    let mut t = Table::new(vec![
        "dataset", "op", "fwd ms", "bwd ms", "+RSC bwd ms", "speedup", "bucket",
    ]);
    let mut rng = Rng::new(0xB2);
    let mut any = false;
    for name in PAPER_DATASETS {
        let b = match XlaBackend::load(name) {
            Ok(b) => b,
            Err(e) => {
                eprintln!("skipping {name}: {e:#}");
                continue;
            }
        };
        any = true;
        let ds = load_or_generate(name, 0)?;
        let caps = b.manifest().dataset.caps.clone();
        let d = ds.cfg.d_h;
        for (label, matrix) in [
            ("SpMM", ds.adj.gcn_normalize()),
            ("SpMM_MEAN", ds.adj.mean_normalize()),
        ] {
            let r = measure(&b, &matrix, &caps, d, iters, 0.1, &mut rng)?;
            t.row(vec![
                name.to_string(),
                label.to_string(),
                format!("{:.2}", r.fwd_ms),
                format!("{:.2}", r.bwd_exact_ms),
                format!("{:.2}", r.bwd_rsc_ms),
                format!("{:.2}x", r.bwd_exact_ms / r.bwd_rsc_ms),
                format!("{}/{}", r.cap, caps.last().unwrap()),
            ]);
        }
    }
    if any {
        t.print();
    } else {
        println!("(no XLA artifacts — see README.md §Artifacts for the AOT flow)");
    }
    println!("paper (Table 2): bwd speedups 11.6/3.5/2.9/9.0x (SpMM), 5.9/1.8/8.3/4.4x (MEAN)");
    Ok(())
}
