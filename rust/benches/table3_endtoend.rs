//! Table 3: end-to-end accuracy + wall-clock speedup for GraphSAINT /
//! GCN / GraphSAGE / GCNII across the four datasets, at the paper's
//! per-cell budgets.  The shape to hold: negligible metric drop with
//! 1.1-1.6x speedups (smallest for SAINT; largest for full-batch on
//! dense-degree graphs).
//!
//! Default scale is CI-sized; RSC_BENCH_FULL=1 RSC_BENCH_EPOCHS=300
//! RSC_BENCH_TRIALS=5 approaches the paper's protocol.

use rsc::bench::harness::{header, BenchScale};
use rsc::bench::support::{
    paper_budget, paper_cell_exists, prefetch_on_vs_off, run_pair, PAPER_DATASETS,
};
use rsc::coordinator::RscConfig;
use rsc::model::ops::ModelKind;
use rsc::runtime::XlaBackend;
use rsc::util::stats::Table;

fn main() -> anyhow::Result<()> {
    header("table3", "end-to-end metric + speedup (4 models x 4 datasets)");
    let scale = BenchScale::from_env(1, 60);
    let mut t = Table::new(vec![
        "model", "dataset", "baseline", "+RSC", "C", "speedup",
    ]);
    for model in [ModelKind::Saint, ModelKind::Gcn, ModelKind::Sage, ModelKind::Gcnii] {
        for dataset in PAPER_DATASETS {
            if !paper_cell_exists(model, dataset) {
                continue;
            }
            let b = XlaBackend::load(dataset)?;
            let c = paper_budget(model, dataset);
            let rsc = RscConfig { budget_c: c, ..Default::default() };
            let (base, with, speedup) =
                run_pair(&b, dataset, model, rsc, scale.epochs, scale.trials)?;
            t.row(vec![
                model.name().to_string(),
                dataset.to_string(),
                base.metric_pm(),
                with.metric_pm(),
                format!("{c}"),
                format!("{speedup:.2}x"),
            ]);
            // stream rows as they land — full sweeps take a while
            println!(
                "{:<8} {:<13} base {}  rsc {}  C={}  {:.2}x",
                model.name(),
                dataset,
                base.metric_pm(),
                with.metric_pm(),
                c,
                speedup
            );
        }
    }
    println!();
    t.print();
    println!("paper (Table 3): drops <=0.3 points, speedups 1.04-1.60x");

    header(
        "table3/prefetch",
        "end-to-end effect of background-prefetched refreshes (GCN, native \
         backend, default cadence; bitwise-equal results)",
    );
    let mut tf = Table::new(vec![
        "dataset",
        "wall (sync)",
        "wall (prefetch)",
        "hot sample ms (sync)",
        "hot sample ms (prefetch)",
        "hit rate",
    ]);
    for dataset in PAPER_DATASETS {
        let r = prefetch_on_vs_off(dataset, scale.epochs)?;
        tf.row(vec![
            dataset.to_string(),
            format!("{:.2}s", r.wall_off_s),
            format!("{:.2}s", r.wall_on_s),
            format!("{:.3}", r.sample_ms_off),
            format!("{:.3}", r.sample_ms_on),
            format!("{:.0}%", 100.0 * r.pf.hit_rate()),
        ]);
        println!(
            "{dataset:<13} hot-path sampling {:.3}ms -> {:.3}ms ({:.0}% of \
             refreshes prefetched, {:.3}ms absorbed by background workers)",
            r.sample_ms_off,
            r.sample_ms_on,
            100.0 * r.pf.hit_rate(),
            r.bg_build_ms
        );
    }
    tf.print();
    println!("every refresh's sample_ms leaves the critical path once prefetched");

    header(
        "table3/models",
        "model coverage: every registered full-batch architecture through the \
         tape executor under RSC (native synthesized catalog, reddit-sim)",
    );
    let mut tm = Table::new(vec![
        "model", "sites", "baseline", "+RSC", "speedup",
    ]);
    let b = rsc::runtime::NativeBackend::synthesize("reddit-sim")?;
    let site_cfg = rsc::data::dataset_cfg("reddit-sim")?;
    for model in ModelKind::FULL_BATCH {
        let rsc_cfg = RscConfig { budget_c: 0.3, ..Default::default() };
        let (base, with, speedup) =
            run_pair(&b, "reddit-sim", model, rsc_cfg, scale.epochs, scale.trials)?;
        let sites = model.n_spmm_bwd(&site_cfg);
        tm.row(vec![
            model.name().to_string(),
            sites.to_string(),
            base.metric_pm(),
            with.metric_pm(),
            format!("{speedup:.2}x"),
        ]);
        println!(
            "{:<6} sites={sites:<2} base {}  rsc {}  {:.2}x",
            model.name(),
            base.metric_pm(),
            with.metric_pm(),
            speedup
        );
    }
    println!();
    tm.print();
    println!(
        "new architectures are pure graph definitions: GIN rides the GCN ops \
         over the sum matrix; APPNP's {} power steps give the allocator its \
         deepest site ladder",
        site_cfg.appnp_layers
    );
    Ok(())
}
