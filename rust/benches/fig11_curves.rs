//! Figure 11: validation-accuracy learning curves on reddit-sim under
//! different budgets C (caching/switching disabled to isolate C's
//! effect).  Shape to hold: larger C converges closer to the exact
//! baseline; small C plateaus lower / noisier.

use rsc::bench::harness::{header, BenchScale};
use rsc::bench::support::run_trials;
use rsc::coordinator::RscConfig;
use rsc::model::ops::ModelKind;
use rsc::runtime::XlaBackend;
use rsc::util::stats::Table;

fn main() -> anyhow::Result<()> {
    header("fig11", "validation curves vs budget C (GCN, reddit-sim)");
    let scale = BenchScale::from_env(1, 100);
    let dataset = "reddit-sim";
    let b = XlaBackend::load(dataset)?;
    let mut curves: Vec<(String, Vec<(usize, f64)>)> = Vec::new();
    for c in [0.05, 0.1, 0.3, 0.5, 1.0] {
        let rsc = if c >= 1.0 {
            RscConfig::baseline()
        } else {
            RscConfig {
                budget_c: c,
                refresh_every: 1,
                switch_frac: 1.0,
                ..Default::default()
            }
        };
        let r = run_trials(&b, dataset, ModelKind::Gcn, rsc, scale.epochs, 1)?;
        let label = if c >= 1.0 { "exact".to_string() } else { format!("C={c}") };
        curves.push((label, r.last.unwrap().val_curve));
    }
    let mut headers = vec!["epoch".to_string()];
    headers.extend(curves.iter().map(|(l, _)| l.clone()));
    let mut t = Table::new(headers);
    let epochs: Vec<usize> = curves[0].1.iter().map(|(e, _)| *e).collect();
    for (i, e) in epochs.iter().enumerate() {
        let mut row = vec![e.to_string()];
        for (_, curve) in &curves {
            row.push(
                curve
                    .get(i)
                    .map(|(_, v)| format!("{:.4}", v))
                    .unwrap_or_default(),
            );
        }
        t.row(row);
    }
    t.print();
    println!("paper (Fig. 11): larger C tracks the exact curve; small C lags/noisier");
    Ok(())
}
