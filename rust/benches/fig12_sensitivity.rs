//! Figure 12: hyperparameter sensitivity of RSC (GraphSAGE on
//! proteins-sim): the budget C, the greedy step size alpha, and the
//! switch-back point.  Shape to hold: larger C = better metric / less
//! speedup; alpha barely matters; later switch-back = faster but larger
//! drop.

use rsc::bench::harness::{header, BenchScale};
use rsc::bench::support::{run_trials, RunStats};
use rsc::coordinator::RscConfig;
use rsc::model::ops::ModelKind;
use rsc::runtime::XlaBackend;
use rsc::util::stats::Table;

fn main() -> anyhow::Result<()> {
    header("fig12", "sensitivity: C, alpha, switch point (SAGE, proteins-sim)");
    let scale = BenchScale::from_env(1, 60);
    let dataset = "proteins-sim";
    let model = ModelKind::Sage;
    let b = XlaBackend::load(dataset)?;
    let base = run_trials(&b, dataset, model, RscConfig::baseline(), scale.epochs, scale.trials)?;
    println!("baseline: {} @ {:.2}s\n", base.metric_pm(), base.wall_mean());
    let run = |rsc: RscConfig| -> anyhow::Result<RunStats> {
        run_trials(&b, dataset, model, rsc, scale.epochs, scale.trials)
    };

    let mut t = Table::new(vec!["knob", "value", "AUC", "speedup"]);
    for c in [0.1, 0.3, 0.5] {
        let r = run(RscConfig { budget_c: c, ..Default::default() })?;
        t.row(vec![
            "budget C".into(),
            format!("{c}"),
            r.metric_pm(),
            format!("{:.2}x", base.wall_mean() / r.wall_mean()),
        ]);
    }
    for alpha in [0.01, 0.02, 0.05, 0.1] {
        let r = run(RscConfig { budget_c: 0.3, alpha, ..Default::default() })?;
        t.row(vec![
            "step alpha".into(),
            format!("{alpha}"),
            r.metric_pm(),
            format!("{:.2}x", base.wall_mean() / r.wall_mean()),
        ]);
    }
    for sw in [0.6, 0.7, 0.8, 0.9, 1.0] {
        let r = run(RscConfig { budget_c: 0.3, switch_frac: sw, ..Default::default() })?;
        t.row(vec![
            "switch at".into(),
            format!("{:.0}%", sw * 100.0),
            r.metric_pm(),
            format!("{:.2}x", base.wall_mean() / r.wall_mean()),
        ]);
    }
    t.print();
    println!("paper (Fig. 12): C trades metric for speed; alpha ~flat; later switch = faster/worse");
    Ok(())
}
