//! Figure 7: the layer-wise k_l that the greedy allocator assigns over
//! the course of training (reddit-sim, C=0.1) for GCN, GraphSAGE and
//! GCNII.  Shape to hold: allocation is non-uniform and evolves with
//! training (deeper layers keep different budgets than shallow ones).

use rsc::bench::harness::{header, BenchScale};
use rsc::bench::support::run_trials;
use rsc::coordinator::RscConfig;
use rsc::model::ops::ModelKind;
use rsc::runtime::XlaBackend;
use rsc::util::stats::Table;

fn main() -> anyhow::Result<()> {
    header("fig7", "allocated k_l per layer across training (C=0.1)");
    let scale = BenchScale::from_env(1, 80);
    let dataset = "reddit-sim";
    let b = XlaBackend::load(dataset)?;
    for model in [ModelKind::Gcn, ModelKind::Sage, ModelKind::Gcnii] {
        let rsc = RscConfig { budget_c: 0.1, switch_frac: 1.0, ..Default::default() };
        let r = run_trials(&b, dataset, model, rsc, scale.epochs, 1)?;
        let res = r.last.as_ref().unwrap();
        println!("\n{} (test {} = {:.4}):", model.name(), res.metric.name(), res.test_metric);
        let sites = res.alloc_history.first().map(|(_, ks)| ks.len()).unwrap_or(0);
        let mut headers = vec!["step".to_string()];
        headers.extend((0..sites).map(|s| format!("k_{s}")));
        let mut t = Table::new(headers);
        let stride = (res.alloc_history.len() / 10).max(1);
        for (step, ks) in res.alloc_history.iter().step_by(stride) {
            let mut row = vec![step.to_string()];
            row.extend(ks.iter().map(|k| k.to_string()));
            t.row(row);
        }
        t.print();
        // non-uniformity check
        if let Some((_, ks)) = res.alloc_history.last() {
            let spread = ks.iter().max().unwrap() - ks.iter().min().unwrap();
            println!("final spread max-min = {spread} (uniform would be 0)");
        }
    }
    println!("\npaper (Fig. 7): k_l differs across layers and drifts during training");
    Ok(())
}
