//! Kernel-level microbenches with a machine-readable trail: times every
//! planned-SpMM kernel variant (scalar / axpy4 / SIMD-tiled) across
//! feature widths, the SIMD-dispatch on/off cost of the dense matmul,
//! Adam, softmax loss and row-norm kernels, and the autotuner's raced
//! winner against the static heuristic's pick per width, then appends
//! one run to `BENCH_kernels.json` so the repo's perf trajectory
//! accumulates across PRs (schema `rsc-bench-kernels/v1`; rows are
//! `{op, variant, dims, ns_per_iter, speedup_vs_scalar}` — the
//! `spmm_autotuned` rows baseline against the heuristic instead).
//!
//! Usage:
//!   cargo bench --bench kernels              # full run, reddit-sim graph
//!   cargo bench --bench kernels -- --smoke   # seconds-scale CI smoke
//!   RSC_BENCH_OUT=path.json ...              # redirect the JSON
//!
//! All compared variants are bitwise identical (asserted inside the
//! runners); this bench measures throughput only.

use rsc::bench::harness::header;
use rsc::bench::support::{
    append_bench_kernels_json, autotune_rows, simd_dispatch_rows, spmm_variant_rows,
    GraphFixture,
};
use rsc::runtime::simd;
use rsc::util::stats::Table;

fn main() -> anyhow::Result<()> {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let iters = if smoke { 3 } else { 15 };
    let dataset = if smoke { "tiny" } else { "reddit-sim" };
    header(
        "kernels",
        &format!(
            "kernel variants on {dataset} (avx {}){}",
            if simd::available() { "available" } else { "absent: simd == scalar" },
            if smoke { " [smoke]" } else { "" }
        ),
    );
    let fx = GraphFixture::gcn(dataset)?;
    let widths: &[usize] = if smoke { &[16, 64] } else { &[16, 64, 128, 256] };

    let spmm = spmm_variant_rows(&fx, widths, iters);
    let mut t = Table::new(vec![
        "d",
        "tile",
        "scalar ms",
        "axpy4 ms",
        "simd ms",
        "simd vs axpy4",
        "simd vs scalar",
    ]);
    for r in &spmm {
        t.row(vec![
            r.d.to_string(),
            r.tile.to_string(),
            format!("{:.3}", r.scalar_ms),
            format!("{:.3}", r.axpy4_ms),
            format!("{:.3}", r.simd_ms),
            format!("{:.2}x", r.simd_vs_axpy4()),
            format!("{:.2}x", r.simd_vs_scalar()),
        ]);
    }
    t.print();

    let dispatch = simd_dispatch_rows(&fx, iters);
    let mut td = Table::new(vec!["op", "dims", "scalar ms", "simd ms", "speedup"]);
    for r in &dispatch {
        td.row(vec![
            r.op.clone(),
            r.dims.clone(),
            format!("{:.3}", r.scalar_ms),
            format!("{:.3}", r.simd_ms),
            format!("{:.2}x", r.speedup()),
        ]);
    }
    td.print();

    let autotuned = autotune_rows(&fx, widths, iters);
    let mut ta = Table::new(vec![
        "d",
        "heuristic",
        "tuned (source)",
        "heur ms",
        "tuned ms",
        "tuned vs heur",
    ]);
    for r in &autotuned {
        ta.row(vec![
            r.d.to_string(),
            r.heuristic.clone(),
            format!("{} ({})", r.tuned, r.source),
            format!("{:.3}", r.heuristic_ms),
            format!("{:.3}", r.tuned_ms),
            format!("{:.2}x", r.speedup()),
        ]);
    }
    ta.print();

    // cargo runs bench binaries with cwd = the package root (rust/), so
    // the default must target the *repo-root* tracked file explicitly
    let path = std::env::var("RSC_BENCH_OUT")
        .unwrap_or_else(|_| concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_kernels.json").into());
    append_bench_kernels_json(&path, &spmm, &dispatch, &autotuned)?;
    println!("appended run to {path}");
    Ok(())
}
