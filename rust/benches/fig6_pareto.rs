//! Figures 6 / 9 / 10: the accuracy-efficiency Pareto frontier of RSC's
//! greedy allocation vs the uniform baseline, sweeping the budget C with
//! caching and switching DISABLED (the paper's protocol for this figure).
//!
//! Default: GCN on reddit-sim (Fig. 6).  RSC_BENCH_FULL=1 adds
//! proteins-sim (Fig. 9) and yelp-sim (Fig. 10) with SAGE and GCNII.
//!
//! Shape to hold: greedy sits above uniform, most visibly at high speedup.

use rsc::bench::harness::{header, BenchScale};
use rsc::bench::support::{run_trials, RunStats};
use rsc::coordinator::{AllocKind, RscConfig};
use rsc::model::ops::ModelKind;
use rsc::runtime::XlaBackend;
use rsc::util::stats::Table;

fn main() -> anyhow::Result<()> {
    header("fig6/9/10", "Pareto: greedy vs uniform allocation, no cache/switch");
    let scale = BenchScale::from_env(1, 50);
    let budgets = [0.05, 0.1, 0.2, 0.3, 0.5];
    let mut combos: Vec<(&str, ModelKind)> = vec![("reddit-sim", ModelKind::Gcn)];
    if scale.full {
        combos.extend([
            ("proteins-sim", ModelKind::Gcn),
            ("proteins-sim", ModelKind::Sage),
            ("proteins-sim", ModelKind::Gcnii),
            ("yelp-sim", ModelKind::Gcn),
            ("yelp-sim", ModelKind::Sage),
            ("yelp-sim", ModelKind::Gcnii),
        ]);
    }
    for (dataset, model) in combos {
        let b = XlaBackend::load(dataset)?;
        let base = run_trials(
            &b,
            dataset,
            model,
            RscConfig::baseline(),
            scale.epochs,
            scale.trials,
        )?;
        println!(
            "\n{} / {}  (baseline {} @ {:.2}s)",
            model.name(),
            dataset,
            base.metric_pm(),
            base.wall_mean()
        );
        let mut t = Table::new(vec!["C", "strategy", "metric", "speedup"]);
        for alloc in [AllocKind::Greedy, AllocKind::Uniform] {
            for &c in &budgets {
                let r: RunStats = run_trials(
                    &b,
                    dataset,
                    model,
                    RscConfig {
                        budget_c: c,
                        allocator: alloc,
                        refresh_every: 1, // caching off
                        switch_frac: 1.0, // switching off
                        ..Default::default()
                    },
                    scale.epochs,
                    scale.trials,
                )?;
                t.row(vec![
                    format!("{c}"),
                    format!("{alloc:?}"),
                    r.metric_pm(),
                    format!("{:.2}x", base.wall_mean() / r.wall_mean()),
                ]);
            }
        }
        t.print();
    }
    println!("\npaper (Fig. 6/9/10): greedy Pareto-dominates uniform, esp. at high speedup");
    Ok(())
}
