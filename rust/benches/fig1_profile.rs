//! Figure 1: time profiling of a GCN step — SpMM's share of total step
//! time per dataset.  Paper reports SpMM at 70-90% on CUDA; the same
//! dominance should appear on XLA-CPU because the scatter/gather SpMM is
//! memory-bound on any backend.

use rsc::bench::harness::header;
use rsc::data::load_or_generate;
use rsc::profile::profile_gcn_step;
use rsc::runtime::XlaBackend;
use rsc::util::stats::Table;

fn main() -> anyhow::Result<()> {
    header("fig1", "SpMM share of a GCN training step");
    let iters = if std::env::var("RSC_BENCH_FULL").as_deref() == Ok("1") {
        30
    } else {
        10
    };
    let mut t = Table::new(vec![
        "dataset", "SpMM ms", "MatMul ms", "other ms", "SpMM share",
    ]);
    for name in rsc::bench::support::PAPER_DATASETS {
        let b = XlaBackend::load(name)?;
        let ds = load_or_generate(name, 0)?;
        let p = profile_gcn_step(&b, &ds, iters)?;
        t.row(vec![
            name.to_string(),
            format!("{:.2}", p.spmm_ms),
            format!("{:.2}", p.matmul_ms),
            format!("{:.2}", p.other_ms),
            format!("{:.1}%", 100.0 * p.spmm_share()),
        ]);
    }
    t.print();
    println!("paper (Fig. 1): SpMM takes 70-90% of step time on all four datasets");
    Ok(())
}
