//! Figure 4: stability of the top-k selection across iterations — the
//! AUC of predicting step-(t+10)'s top-k membership from step-t's scores,
//! per layer, for GCN and GraphSAGE on reddit-sim.
//!
//! Shape to hold: AUC stays high (>0.9 in the paper) throughout training,
//! which is what justifies the caching mechanism.

use rsc::bench::harness::{header, BenchScale};
use rsc::bench::support::run_trials;
use rsc::coordinator::RscConfig;
use rsc::model::ops::ModelKind;
use rsc::runtime::XlaBackend;
use rsc::util::stats::{self, Table};

fn main() -> anyhow::Result<()> {
    header("fig4", "top-k selection overlap AUC across 10-step gaps");
    let scale = BenchScale::from_env(1, 80);
    let dataset = "reddit-sim";
    let b = XlaBackend::load(dataset)?;
    let mut t = Table::new(vec!["model", "layer", "mean AUC", "min AUC", "samples"]);
    for model in [ModelKind::Gcn, ModelKind::Sage] {
        // caching must be observed but not interfere: refresh every 10
        // (each refresh emits one AUC sample); no switching.
        let rsc = RscConfig { budget_c: 0.3, switch_frac: 1.0, ..Default::default() };
        let r = run_trials(&b, dataset, model, rsc, scale.epochs, 1)?;
        let res = r.last.as_ref().unwrap();
        let sites = model.n_spmm_bwd(&rsc_dataset_cfg(dataset)?);
        for site in 0..sites {
            let xs: Vec<f64> = res
                .overlap_samples
                .iter()
                .filter(|(l, _, _)| *l == site)
                .map(|(_, _, a)| *a)
                .collect();
            if xs.is_empty() {
                continue;
            }
            t.row(vec![
                model.name().to_string(),
                format!("{site}"),
                format!("{:.3}", stats::mean(&xs)),
                format!("{:.3}", xs.iter().cloned().fold(f64::INFINITY, f64::min)),
                xs.len().to_string(),
            ]);
        }
    }
    t.print();
    println!("paper (Fig. 4): AUC ~0.9-1.0 across the whole run for every layer");
    Ok(())
}

fn rsc_dataset_cfg(name: &str) -> anyhow::Result<rsc::data::DatasetCfg> {
    rsc::data::dataset_cfg(name)
}
