//! `shard_scale` — sharded training at 10M-node scale (DESIGN.md
//! §Sharded execution).
//!
//! Synthesizes a power-law graph with the *streaming* generator (two
//! deterministic RNG passes straight into CSR — peak memory is one CSR,
//! never a triple list), then trains one full-batch GCN epoch end-to-end
//! at each `--shards` count and appends a run to `BENCH_shard.json`
//! (schema `rsc-bench-shard/v1`; one row per shard count with nodes,
//! edges, wall-clock, sampling/alloc time, merge counters and the
//! weights fingerprint).  Every row of a run must report the *same*
//! fingerprint — sharding is a pure execution transformation, so the
//! bench asserts the bit-identity contract at full scale instead of
//! trusting the unit suite's small graphs.
//!
//! Usage:
//!   cargo bench --bench shard_scale               # 10M nodes (~6 GB RSS)
//!   cargo bench --bench shard_scale -- --smoke    # 200k nodes, CI-sized
//!   RSC_BENCH_NODES=1000000 ...                   # override node count
//!   RSC_BENCH_OUT=path.json ...                   # redirect the JSON

use rsc::bench::harness::{header, BenchScale};
use rsc::coordinator::{shard, AllocKind, RscConfig};
use rsc::data::scale_free;
use rsc::model::ops::ModelKind;
use rsc::runtime::{Manifest, NativeBackend};
use rsc::train::{train, TrainConfig};
use rsc::util::json::{obj, Json};
use rsc::util::parallel;
use rsc::util::stats::Table;

struct ShardRow {
    shards: usize,
    train_wall_s: f64,
    sample_ms: f64,
    alloc_ms: f64,
    merges: u64,
    merge_edges: u64,
    disagreements: u64,
    fingerprint: u64,
}

fn append_bench_shard_json(
    path: &str,
    nodes: usize,
    edges: usize,
    epochs: usize,
    rows: &[ShardRow],
) -> anyhow::Result<()> {
    let json_rows: Vec<Json> = rows
        .iter()
        .map(|r| {
            obj(vec![
                ("shards", Json::from(r.shards)),
                ("nodes", Json::from(nodes)),
                ("edges", Json::from(edges)),
                ("epochs", Json::from(epochs)),
                ("train_wall_s", Json::from(r.train_wall_s)),
                ("sample_ms", Json::from(r.sample_ms)),
                ("alloc_ms", Json::from(r.alloc_ms)),
                ("merges", Json::from(r.merges as usize)),
                ("merge_edges", Json::from(r.merge_edges as usize)),
                ("disagreements", Json::from(r.disagreements as usize)),
                (
                    "weights_fingerprint",
                    Json::from(format!("{:016x}", r.fingerprint).as_str()),
                ),
            ])
        })
        .collect();
    let run = obj(vec![
        ("unix_time", Json::from(rsc::util::timer::unix_time_s() as f64)),
        ("threads", Json::from(parallel::global().threads())),
        ("rows", Json::Arr(json_rows)),
    ]);
    let mut runs: Vec<Json> = match std::fs::read_to_string(path) {
        Ok(text) => match Json::parse(&text) {
            Ok(j) => j
                .opt("runs")
                .and_then(|r| r.as_arr().ok())
                .map(|r| r.to_vec())
                .unwrap_or_default(),
            Err(_) => Vec::new(),
        },
        Err(_) => Vec::new(),
    };
    runs.push(run);
    let doc = obj(vec![
        ("schema", Json::from("rsc-bench-shard/v1")),
        ("runs", Json::Arr(runs)),
    ]);
    std::fs::write(path, doc.to_string() + "\n")?;
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let scale = BenchScale::from_env(1, if smoke { 2 } else { 1 });
    let nodes = std::env::var("RSC_BENCH_NODES")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .unwrap_or(if smoke { 200_000 } else { 10_000_000 });
    let epochs = scale.epochs.clamp(1, 5);
    let shard_counts: &[usize] = if smoke { &[1, 2, 4] } else { &[1, 2, 4, 8] };
    header(
        "shard_scale",
        &format!(
            "sharded GCN training, {nodes} power-law nodes, {epochs} epoch(s), \
             {} threads{}",
            parallel::global().threads(),
            if smoke { " [smoke]" } else { "" }
        ),
    );

    // one synthesis shared by every shard count (narrow features: the
    // bench measures the sharded sparse backward, not accuracy)
    let ds = scale_free(nodes, 2, 4, 4, 42)?;
    let backend = NativeBackend::from_manifest(Manifest::synthesize_full_batch(&ds.cfg));
    println!(
        "graph: {} nodes, {} directed edges ({} with self-loops)",
        ds.cfg.v,
        ds.cfg.e,
        ds.cfg.m()
    );

    let mut rows: Vec<ShardRow> = Vec::new();
    for &s in shard_counts {
        shard::reset_shard_stats();
        let cfg = TrainConfig {
            epochs,
            seed: 42,
            rsc: RscConfig {
                budget_c: 0.1,
                allocator: AllocKind::Greedy,
                ..Default::default()
            },
            eval_every: epochs.max(1_000_000), // final eval only
            shards: s,
            ..TrainConfig::new(ModelKind::Gcn)
        };
        let res = train(&backend, &ds, &cfg)?;
        let (merges, merge_edges, disagreements) = shard::shard_counter_stats();
        for st in &res.shard_stats {
            println!(
                "  shard {} rows [{}, {}): gather nnz {}  retained {}  sampling {:.1}ms",
                st.shard, st.rows.0, st.rows.1, st.gather_nnz, st.retained, st.sample_ms
            );
        }
        rows.push(ShardRow {
            shards: s,
            train_wall_s: res.train_wall_s,
            sample_ms: res.sample_ms,
            alloc_ms: res.alloc_ms,
            merges,
            merge_edges,
            disagreements,
            fingerprint: res.weights_fingerprint,
        });
    }

    let mut t = Table::new(vec![
        "shards",
        "epoch wall s",
        "sampling ms",
        "alloc ms",
        "merges",
        "fingerprint",
    ]);
    for r in &rows {
        t.row(vec![
            r.shards.to_string(),
            format!("{:.2}", r.train_wall_s / epochs as f64),
            format!("{:.1}", r.sample_ms),
            format!("{:.1}", r.alloc_ms),
            r.merges.to_string(),
            format!("{:016x}", r.fingerprint),
        ]);
    }
    t.print();

    // the contract the whole subsystem hangs on: every shard count
    // produces bit-identical weights (DESIGN.md §Sharded execution)
    let fp0 = rows[0].fingerprint;
    for r in &rows[1..] {
        anyhow::ensure!(
            r.fingerprint == fp0,
            "--shards {} fingerprint {:016x} != --shards {} fingerprint {fp0:016x}",
            r.shards,
            r.fingerprint,
            rows[0].shards
        );
    }
    println!("bit-identity: all {} shard counts agree on {fp0:016x}", rows.len());

    // cargo runs bench binaries with cwd = the package root (rust/), so
    // the default must target the *repo-root* tracked file explicitly
    let path = std::env::var("RSC_BENCH_OUT")
        .unwrap_or_else(|_| concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_shard.json").into());
    append_bench_shard_json(&path, ds.cfg.v, ds.cfg.e, epochs, &rows)?;
    println!("appended run to {path}");
    Ok(())
}
