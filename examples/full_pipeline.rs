//! End-to-end driver (the EXPERIMENTS.md validation run): exercises all
//! three layers of the stack on a real small workload —
//!
//!   1. generate the reddit-sim graph (6k nodes / 150k edges) in Rust,
//!   2. load the AOT op catalog (JAX/Pallas-lowered HLO) via PJRT,
//!   3. train a 3-layer GCN for a few hundred epochs, baseline then RSC,
//!      logging the loss curve,
//!   4. report accuracy, speedup, per-op-class time attribution, and the
//!      coordinator's internals (k_l trajectory, cache hit-rate, overlap
//!      AUC).
//!
//!     cargo run --release --example full_pipeline [epochs]

use rsc::coordinator::RscConfig;
use rsc::data::load_or_generate;
use rsc::model::ops::ModelKind;
use rsc::runtime::XlaBackend;
use rsc::train::{train, TrainConfig, TrainResult};
use rsc::util::stats::Table;

fn sparkline(xs: &[f32]) -> String {
    const BARS: [char; 8] = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
    let lo = xs.iter().cloned().fold(f32::INFINITY, f32::min);
    let hi = xs.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    xs.iter()
        .map(|&x| {
            let t = if hi > lo { (x - lo) / (hi - lo) } else { 0.0 };
            BARS[((t * 7.0).round() as usize).min(7)]
        })
        .collect()
}

fn report(tag: &str, r: &TrainResult) {
    println!("\n[{tag}]");
    println!("  test {} = {:.4} (best val {:.4})", r.metric.name(), r.test_metric, r.best_val);
    println!("  wall {:.2}s over {} epochs", r.train_wall_s, r.loss_curve.len());
    let every = (r.loss_curve.len() / 60).max(1);
    let sampled: Vec<f32> = r.loss_curve.iter().step_by(every).cloned().collect();
    println!(
        "  loss {:.3} -> {:.3}  {}",
        r.loss_curve[0],
        r.loss_curve.last().unwrap(),
        sparkline(&sampled)
    );
    println!("  op-class totals:");
    for label in r.tb.labels().map(str::to_string).collect::<Vec<_>>() {
        println!(
            "    {label:<10} {:>9.1} ms ({:>5} calls, {:.2} ms/call)",
            r.tb.total_ms(&label),
            r.tb.count(&label),
            r.tb.mean_ms(&label)
        );
    }
}

fn main() -> anyhow::Result<()> {
    let epochs: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(300);
    let dataset = "reddit-sim";

    println!("== RSC full pipeline on {dataset} ==");
    let backend = XlaBackend::load(dataset)?;
    let ds = load_or_generate(dataset, 0)?;
    println!(
        "graph: {} nodes, {} edges ({} incl self-loops), {} classes",
        ds.cfg.v,
        ds.cfg.e,
        ds.cfg.m(),
        ds.cfg.n_class
    );

    let mut cfg = TrainConfig::new(ModelKind::Gcn);
    cfg.epochs = epochs;
    cfg.eval_every = (epochs / 20).max(1);

    cfg.rsc = RscConfig::baseline();
    let base = train(&backend, &ds, &cfg)?;
    report("baseline", &base);

    cfg.rsc = RscConfig { budget_c: 0.1, ..Default::default() };
    let rsc = train(&backend, &ds, &cfg)?;
    report("rsc C=0.1", &rsc);

    // coordinator internals
    println!("\n[coordinator]");
    println!(
        "  cache: {} hits / {} misses ({:.0}% hit-rate)",
        rsc.cache_hits,
        rsc.cache_misses,
        100.0 * rsc.cache_hits as f64 / (rsc.cache_hits + rsc.cache_misses).max(1) as f64
    );
    println!("  allocator: {:.1}ms total   sampling: {:.1}ms total", rsc.alloc_ms, rsc.sample_ms);
    if !rsc.overlap_samples.is_empty() {
        let mean: f64 = rsc.overlap_samples.iter().map(|(_, _, a)| a).sum::<f64>()
            / rsc.overlap_samples.len() as f64;
        println!("  top-k overlap AUC across refreshes (Fig. 4): {mean:.3}");
    }
    let mut t = Table::new(vec!["epoch", "k_0", "k_1", "k_2"]);
    for (step, ks) in rsc.alloc_history.iter().step_by(rsc.alloc_history.len() / 8 + 1) {
        t.row(vec![
            step.to_string(),
            ks[0].to_string(),
            ks[1].to_string(),
            ks[2].to_string(),
        ]);
    }
    println!("  allocated k_l trajectory (Fig. 7):");
    print!("{}", t.render());

    println!("\n== summary ==");
    println!(
        "speedup {:.2}x, metric drop {:+.4}",
        base.train_wall_s / rsc.train_wall_s,
        base.test_metric - rsc.test_metric
    );
    Ok(())
}
