//! Memory-regression check for the PJRT runtime: 20k op executions must
//! not grow RSS (the upstream xla crate's literal-path `execute` leaked
//! every input buffer — see EXPERIMENTS.md §Perf change #2; our
//! `buffer_from_host_buffer` + `execute_b` path is leak-free).
//!
//!     cargo run --release --example leaktest [run|literal]

use rsc::runtime::{Backend, XlaBackend, Value};

fn rss_mb() -> f64 {
    let s = std::fs::read_to_string("/proc/self/status").unwrap();
    for l in s.lines() {
        if l.starts_with("VmRSS") {
            let kb: f64 = l.split_whitespace().nth(1).unwrap().parse().unwrap();
            return kb / 1024.0;
        }
    }
    0.0
}

fn main() -> anyhow::Result<()> {
    let b = XlaBackend::load("tiny")?;
    let v = 128usize;
    let d = 16usize;
    let a1 = Value::mat_f32(v, d, vec![0.5; v * d]);
    let mode = std::env::args().nth(1).unwrap_or_else(|| "run".into());
    let start = rss_mb();
    println!("mode {mode}: start {start:.1} MB");
    match mode.as_str() {
        "literal" => {
            for i in 0..200_000 {
                let l = xla::Literal::vec1(&vec![0.5f32; v * d])
                    .reshape(&[v as i64, d as i64])
                    .unwrap();
                std::hint::black_box(&l);
                if i % 50_000 == 0 {
                    println!("iter {i}: {:.1} MB", rss_mb());
                }
            }
        }
        _ => {
            for i in 0..20_000 {
                let out = b.run("add_16", &[a1.clone(), a1.clone()])?;
                std::hint::black_box(&out);
                if i % 5_000 == 0 {
                    println!("iter {i}: {:.1} MB", rss_mb());
                }
            }
        }
    }
    let end = rss_mb();
    println!("end {end:.1} MB");
    // allow warmup growth (compile caches) but not a per-call leak
    assert!(
        end - start < 120.0,
        "RSS grew {:.1} MB over the loop — leak regression",
        end - start
    );
    println!("leaktest OK");
    Ok(())
}
