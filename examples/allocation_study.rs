//! Allocation study: the greedy allocator (Alg. 1) vs the uniform
//! baseline vs the exact DP solver, on a real generated graph with
//! realistic gradient-norm skew.  A miniature of Figure 6's message:
//! under the same FLOPs budget, greedy keeps more score mass (lower
//! approximation error), especially at tight budgets.
//!
//!     cargo run --release --example allocation_study

use rsc::allocator::{
    evaluate, total_budget, Allocator, DpExact, GreedyAllocator, LayerScores,
    UniformAllocator,
};
use rsc::data::load_or_generate;
use rsc::sampling::pair_scores;
use rsc::util::rng::Rng;
use rsc::util::stats::Table;
use rsc::util::timer::Stopwatch;

fn main() -> anyhow::Result<()> {
    let ds = load_or_generate("tiny", 0)?;
    let matrix = ds.adj.gcn_normalize();
    let col = matrix.row_norms();
    let nnz: Vec<u32> = (0..matrix.n).map(|r| matrix.row_nnz(r) as u32).collect();
    let mut rng = Rng::new(42);

    // simulate per-layer gradient norms with increasing skew (deeper
    // layers concentrate gradient mass, like Fig. 7 shows)
    let layers: Vec<LayerScores> = (0..3)
        .map(|i| {
            let g: Vec<f32> = (0..matrix.n)
                .map(|_| rng.f32().powf(1.0 + 2.0 * i as f32))
                .collect();
            LayerScores { scores: pair_scores(&col, &g), nnz: nnz.clone(), d: 16 }
        })
        .collect();

    let mut t = Table::new(vec![
        "C", "strategy", "k per layer", "kept score", "flops/budget", "time",
    ]);
    for c in [0.05, 0.1, 0.2, 0.3, 0.5] {
        let budget = total_budget(&layers, c);
        let strategies: Vec<(&str, Box<dyn Allocator>)> = vec![
            ("greedy", Box::new(GreedyAllocator::default())),
            ("uniform", Box::new(UniformAllocator)),
            (
                "dp-exact",
                Box::new(DpExact { alpha: 0.05, min_frac: 0.02, ..Default::default() }),
            ),
        ];
        for (name, alloc) in strategies {
            let sw = Stopwatch::start();
            let ks = alloc.allocate(&layers, c);
            let ms = sw.ms();
            let (kept, flops) = evaluate(&layers, &ks);
            t.row(vec![
                format!("{c:.2}"),
                name.to_string(),
                format!("{ks:?}"),
                format!("{kept:.4}"),
                format!("{:.2}", flops as f64 / budget.max(1) as f64),
                format!("{ms:.2}ms"),
            ]);
        }
    }
    t.print();
    println!(
        "\nnote: kept score = sum of normalized retained pair mass (higher is\n\
         better, 3.0 = everything); uniform often overshoots the budget\n\
         (flops/budget > 1) because k alone cannot control sparse FLOPs —\n\
         exactly the paper's Section 3.2 motivation."
    );
    Ok(())
}
