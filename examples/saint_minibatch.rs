//! GraphSAINT mini-batch training with RSC: the subgraph-sampled setting
//! of Table 3's first row.  Pre-samples random-walk subgraphs offline
//! (paper footnote 1), pads them to the AOT shapes, and applies the
//! caching mechanism per subgraph.
//!
//!     cargo run --release --example saint_minibatch [dataset]

use rsc::coordinator::RscConfig;
use rsc::data::{load_or_generate, SaintSampler};
use rsc::model::ops::ModelKind;
use rsc::runtime::XlaBackend;
use rsc::train::{train, TrainConfig};
use rsc::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let dataset = std::env::args().nth(1).unwrap_or_else(|| "reddit-sim".into());
    let backend = XlaBackend::load(&dataset)?;
    let ds = load_or_generate(&dataset, 0)?;
    anyhow::ensure!(ds.cfg.saint_v > 0, "{dataset} has no SAINT configuration");

    // show what the sampler produces
    let sampler = SaintSampler::for_dataset(&ds);
    let mut rng = Rng::new(1);
    println!("sampler: {} roots, walk length {}", sampler.roots, sampler.walk_len);
    for i in 0..3 {
        let sg = sampler.sample(&ds, &mut rng);
        println!(
            "  subgraph {i}: {} nodes ({} cap), {} edges ({} cap)",
            sg.n_real,
            sg.v_cap,
            sg.adj.nnz(),
            sg.m_cap
        );
    }

    let mut cfg = TrainConfig::new(ModelKind::Saint);
    cfg.epochs = 40;
    cfg.eval_every = 5;
    cfg.saint_subgraphs = 8;
    cfg.saint_batches_per_epoch = 4;

    println!("\n--- GraphSAINT baseline ---");
    cfg.rsc = RscConfig::baseline();
    let base = train(&backend, &ds, &cfg)?;
    println!(
        "baseline: test {} = {:.4}, wall {:.2}s",
        base.metric.name(),
        base.test_metric,
        base.train_wall_s
    );

    println!("\n--- GraphSAINT + RSC (C=0.1) ---");
    cfg.rsc = RscConfig { budget_c: 0.1, ..Default::default() };
    let rsc = train(&backend, &ds, &cfg)?;
    println!(
        "rsc:      test {} = {:.4}, wall {:.2}s",
        rsc.metric.name(),
        rsc.test_metric,
        rsc.train_wall_s
    );

    println!(
        "\nspeedup {:.2}x, drop {:+.4} (paper reports ~1.1x for SAINT — the\n\
         mini-batch setting is transfer-bound, Section 6.2.1)",
        base.train_wall_s / rsc.train_wall_s,
        base.test_metric - rsc.test_metric
    );
    Ok(())
}
