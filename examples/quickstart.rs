//! Quickstart: train a GCN on the Reddit-scale synthetic benchmark with
//! and without RSC, and print the accuracy + speedup comparison.
//!
//!     make artifacts && cargo run --release --example quickstart
//!
//! Expect: matching accuracy, >1.3x wall-clock speedup at C=0.1.

use rsc::coordinator::RscConfig;
use rsc::data::load_or_generate;
use rsc::model::ops::ModelKind;
use rsc::runtime::XlaBackend;
use rsc::train::{train, TrainConfig};

fn main() -> anyhow::Result<()> {
    let dataset = "reddit-sim";
    let epochs = 100;
    println!("loading AOT artifacts for {dataset} ...");
    let backend = XlaBackend::load(dataset)?;
    let ds = load_or_generate(dataset, 0)?;

    let mut cfg = TrainConfig::new(ModelKind::Gcn);
    cfg.epochs = epochs;
    cfg.eval_every = 10;

    println!("\n--- baseline (exact sparse ops) ---");
    cfg.rsc = RscConfig::baseline();
    let base = train(&backend, &ds, &cfg)?;
    println!(
        "baseline: test {} = {:.4}, wall {:.2}s",
        base.metric.name(),
        base.test_metric,
        base.train_wall_s
    );

    println!("\n--- RSC (C=0.1, greedy allocation + caching + switching) ---");
    cfg.rsc = RscConfig { budget_c: 0.1, ..Default::default() };
    let rsc = train(&backend, &ds, &cfg)?;
    println!(
        "rsc:      test {} = {:.4}, wall {:.2}s",
        rsc.metric.name(),
        rsc.test_metric,
        rsc.train_wall_s
    );

    println!("\n== summary ==");
    println!(
        "accuracy drop: {:+.4}   speedup: {:.2}x   cache hit-rate: {:.0}%",
        base.test_metric - rsc.test_metric,
        base.train_wall_s / rsc.train_wall_s,
        100.0 * rsc.cache_hits as f64 / (rsc.cache_hits + rsc.cache_misses).max(1) as f64,
    );
    println!(
        "allocator overhead: {:.1}ms total   sampling: {:.1}ms total",
        rsc.alloc_ms, rsc.sample_ms
    );
    Ok(())
}
