"""AOT driver: lower the whole op catalog to HLO text + manifest.json.

Interchange format is HLO **text**, not ``.serialize()``: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1 (the
version the published ``xla`` 0.1.6 rust crate links) rejects
(``proto.id() <= INT_MAX``).  The text parser reassigns ids, so text
round-trips cleanly (see /opt/xla-example/README.md).

Layout:
  artifacts/<dataset>/<op>.hlo.txt
  artifacts/<dataset>/manifest.json   # shapes + metadata the rust runtime
                                      # validates against its own config

Usage:  cd python && python -m compile.aot --out ../artifacts [--datasets a,b]
"""

import argparse
import hashlib
import json
import os
import time

import jax

from . import model
from .kernels import ref


def to_hlo_text(lowered) -> str:
    from jax._src.lib import xla_client as xc

    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _dtype_name(dt) -> str:
    return {"float32": "f32", "int32": "i32"}[str(dt)]


def lower_op(op: model.OpSpec):
    lowered = jax.jit(op.fn).lower(*op.args)
    text = to_hlo_text(lowered)
    out_avals = jax.eval_shape(op.fn, *op.args)
    if not isinstance(out_avals, (tuple, list)):
        out_avals = (out_avals,)
    entry = {
        "name": op.name,
        "file": f"{op.name}.hlo.txt",
        "inputs": [
            {"dtype": _dtype_name(a.dtype), "shape": list(a.shape)}
            for a in op.args
        ],
        "outputs": [
            {"dtype": _dtype_name(a.dtype), "shape": list(a.shape)}
            for a in out_avals
        ],
        "meta": op.meta,
    }
    return text, entry


def emit_dataset(cfg: model.DatasetCfg, out_dir: str, fwd_caps: bool) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    ops = model.build_catalog(cfg, fwd_caps=fwd_caps)
    entries = []
    t0 = time.time()
    for i, op in enumerate(ops):
        text, entry = lower_op(op)
        with open(os.path.join(out_dir, entry["file"]), "w") as f:
            f.write(text)
        entries.append(entry)
    manifest = {
        "dataset": {
            "name": cfg.name,
            "v": cfg.v,
            "e": cfg.e,
            "m": cfg.full.m,
            "d_in": cfg.d_in,
            "d_h": cfg.d_h,
            "n_class": cfg.n_class,
            "multilabel": cfg.multilabel,
            "layers": cfg.layers,
            "gcnii_layers": cfg.gcnii_layers,
            "gcnii_alpha": cfg.gcnii_alpha,
            "gcnii_lambda": cfg.gcnii_lambda,
            "saint_v": cfg.saint_v,
            "saint_m": cfg.saint_m,
            "caps": cfg.full.caps,
            "saint_caps": cfg.saint.caps if cfg.saint_v else [],
        },
        "ops": entries,
    }
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(
        f"  {cfg.name}: {len(entries)} ops in {time.time() - t0:.1f}s -> {out_dir}"
    )
    return manifest


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--out", default="../artifacts")
    p.add_argument(
        "--datasets",
        default="tiny,reddit-sim,yelp-sim,proteins-sim,products-sim",
        help="comma-separated subset of dataset configs to emit",
    )
    args = p.parse_args()
    names = [n for n in args.datasets.split(",") if n]
    t0 = time.time()
    for name in names:
        cfg = model.DATASETS[name]
        # Table 1 needs reduced-cap *forward* ops: reddit + tiny only.
        fwd_caps = name in ("reddit-sim", "tiny")
        emit_dataset(cfg, os.path.join(args.out, name), fwd_caps)
    print(f"total {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
