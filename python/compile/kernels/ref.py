"""Pure-jnp oracles for every L1 kernel.

These are the correctness references: pytest checks each Pallas kernel
against the functions here, and the Rust native backend (rust/src/runtime/
native.rs) mirrors the same semantics so the XLA executables can be
cross-checked end-to-end.

Sparse-matrix convention (shared with the Rust side):
  A sparse matrix is an edge list ``(src, dst, w)`` of equal-length 1-D
  arrays.  ``spmm(src, dst, w, x)[v] = sum_{e: dst[e]=v} w[e] * x[src[e]]``
  i.e. out = S @ x where S[dst[e], src[e]] += w[e].  Padding edges use
  ``w = 0`` (and any valid src/dst index), so padded buckets are exact.
"""

import jax.numpy as jnp


def spmm_ref(src, dst, w, x, n_out):
    """Edge-list SpMM: out[v] = sum over incoming edges of w * x[src]."""
    msgs = x[src] * w[:, None]
    return jnp.zeros((n_out, x.shape[1]), x.dtype).at[dst].add(msgs)


def spmm_mean_ref(src, dst, x, n_out):
    """SpMM_MEAN (Appendix A.3): mean reducer over incoming neighbours.

    Equivalent to D^-1 A x where D counts incoming edges; rows with no
    incoming edge produce zeros (0/1 guard) to avoid NaN.
    """
    ones = jnp.ones((src.shape[0],), x.dtype)
    deg = jnp.zeros((n_out,), x.dtype).at[dst].add(ones)
    summed = jnp.zeros((n_out, x.shape[1]), x.dtype).at[dst].add(x[src])
    return summed / jnp.maximum(deg, 1.0)[:, None]


def matmul_ref(x, y):
    return jnp.dot(x, y, preferred_element_type=jnp.float32)


def relu_ref(x):
    return jnp.maximum(x, 0.0)


def relu_bwd_ref(out, g):
    """d/dx relu given the *output* (mask out>0 == pre-activation>0)."""
    return g * (out > 0.0).astype(g.dtype)


def row_norms_ref(x):
    """L2 norm of each row."""
    return jnp.sqrt(jnp.sum(x * x, axis=1))


def approx_spmm_ref(src, dst, w, x, n_out, keep):
    """Column-row sampled SpMM: drop every edge whose *source* row is not
    in the keep set (top-k column-row pair selection of Section 3.2).

    ``keep`` is a boolean [n_in] mask.  This is the oracle the padded
    bucket executables must match: selecting pairs S keeps exactly the
    edges with src in S.
    """
    w_sel = w * keep[src].astype(w.dtype)
    return spmm_ref(src, dst, w_sel, x, n_out)


def softmax_xent_ref(logits, labels, mask):
    """Masked mean softmax cross-entropy -> (loss, dlogits)."""
    zmax = jnp.max(logits, axis=1, keepdims=True)
    z = logits - zmax
    lse = jnp.log(jnp.sum(jnp.exp(z), axis=1, keepdims=True))
    logp = z - lse
    n = jnp.maximum(jnp.sum(mask), 1.0)
    onehot = jnp.zeros_like(logits).at[jnp.arange(logits.shape[0]), labels].set(1.0)
    loss = -jnp.sum(jnp.sum(onehot * logp, axis=1) * mask) / n
    dlogits = (jnp.exp(logp) - onehot) * (mask / n)[:, None]
    return loss, dlogits


def bce_logits_ref(logits, labels, mask):
    """Masked mean binary cross-entropy with logits -> (loss, dlogits)."""
    n = jnp.maximum(jnp.sum(mask), 1.0) * logits.shape[1]
    # log(1+exp(x)) stable form
    sp = jnp.maximum(logits, 0.0) + jnp.log1p(jnp.exp(-jnp.abs(logits)))
    loss = jnp.sum((sp - logits * labels) * mask[:, None]) / n
    sig = 1.0 / (1.0 + jnp.exp(-logits))
    dlogits = (sig - labels) * (mask / n)[:, None]
    return loss, dlogits


def adam_ref(w, m, v, g, t, lr, beta1=0.9, beta2=0.999, eps=1e-8):
    m2 = beta1 * m + (1.0 - beta1) * g
    v2 = beta2 * v + (1.0 - beta2) * g * g
    mhat = m2 / (1.0 - beta1**t)
    vhat = v2 / (1.0 - beta2**t)
    w2 = w - lr * mhat / (jnp.sqrt(vhat) + eps)
    return w2, m2, v2
