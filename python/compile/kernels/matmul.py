"""L1 Pallas dense-matmul and row-norm kernels (interpret=True).

``matmul`` is the classic MXU-aligned tiled kernel: the grid walks
(m/bm, n/bn, k/bk) tiles, accumulating partial products into the output
tile across the k dimension (k is the innermost, sequential grid axis).
Tile sizes default to 128 — the MXU systolic-array edge — and inputs are
zero-padded up to tile multiples by the wrapper, so any shape works.

``row_norms`` computes per-row L2 norms with a row-tiled grid; it is the
allocator's input (\\|nabla H_i\\|_2 in Eq. 4a) and must be cheap.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _cdiv(a, b):
    return (a + b - 1) // b


def _pad2(x, bm, bn):
    m, n = x.shape
    pm, pn = _cdiv(m, bm) * bm, _cdiv(n, bn) * bn
    if (pm, pn) == (m, n):
        return x
    return jnp.pad(x, ((0, pm - m), (0, pn - n)))


def matmul(x, y, bm=128, bn=128, bk=128):
    """Tiled matmul: f32 accumulate, MXU-aligned 128x128x128 tiles."""
    m, k = x.shape
    k2, n = y.shape
    assert k == k2, (x.shape, y.shape)
    xp = _pad2(x, bm, bk)
    yp = _pad2(y, bk, bn)
    gm, gn, gk = xp.shape[0] // bm, yp.shape[1] // bn, xp.shape[1] // bk

    def kernel(x_ref, y_ref, o_ref):
        @pl.when(pl.program_id(2) == 0)
        def _init():
            o_ref[...] = jnp.zeros_like(o_ref)

        o_ref[...] += jnp.dot(
            x_ref[...], y_ref[...], preferred_element_type=jnp.float32
        )

    out = pl.pallas_call(
        kernel,
        grid=(gm, gn, gk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, l: (i, l)),
            pl.BlockSpec((bk, bn), lambda i, j, l: (l, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, l: (i, j)),
        out_shape=jax.ShapeDtypeStruct((xp.shape[0], yp.shape[1]), jnp.float32),
        interpret=True,
    )(xp, yp)
    return out[:m, :n]


def row_norms(x, block_rows=1024):
    """Per-row L2 norms, row-tiled."""
    m, d = x.shape
    pm = _cdiv(m, block_rows) * block_rows
    xp = jnp.pad(x, ((0, pm - m), (0, 0))) if pm != m else x

    def kernel(x_ref, o_ref):
        v = x_ref[...]
        o_ref[...] = jnp.sqrt(jnp.sum(v * v, axis=1))

    out = pl.pallas_call(
        kernel,
        grid=(pm // block_rows,),
        in_specs=[pl.BlockSpec((block_rows, d), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((block_rows,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((pm,), x.dtype),
        interpret=True,
    )(xp)
    return out[:m]
