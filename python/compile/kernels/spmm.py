"""L1 Pallas SpMM kernels (interpret=True).

Two designs, mirroring the DESIGN.md §Hardware-Adaptation discussion:

``spmm_edgeblock``
    Edge-parallel streaming: the grid walks fixed-size blocks of the COO
    edge stream; every block gathers ``x[src]`` rows into the tile,
    scales by ``w`` and scatter-adds into the full output.  Simple and
    shape-agnostic, but the output tile is revisited by every grid step
    (the CUDA-atomics analogue) — it is the correctness/baseline kernel.

``spmm_rowtile``
    TPU-shaped: edges are pre-sorted by destination row and padded into
    per-row-tile segments of equal capacity, so each grid step owns a
    *disjoint* output row tile (BlockSpec expresses the HBMto-VMEM
    schedule; no revisiting, no atomics).  This is the kernel a real
    Mosaic lowering would use; ``rowtile_pack`` is the build-time
    preprocessing that the Rust coordinator mirrors for cached samples.

Both are validated against ``ref.spmm_ref`` by pytest/hypothesis.
Padding convention: padded edges carry ``w == 0`` (src/dst point at row 0)
so results are exact for any capacity >= nnz.
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl


def _cdiv(a, b):
    return (a + b - 1) // b


def _pad_edges(src, dst, w, multiple):
    e = src.shape[0]
    pe = _cdiv(max(e, 1), multiple) * multiple
    if pe == e:
        return src, dst, w
    pad = pe - e
    src = jnp.concatenate([src, jnp.zeros((pad,), src.dtype)])
    dst = jnp.concatenate([dst, jnp.zeros((pad,), dst.dtype)])
    w = jnp.concatenate([w, jnp.zeros((pad,), w.dtype)])
    return src, dst, w


def spmm_edgeblock(src, dst, w, x, n_out, block_e=4096):
    """Edge-blocked SpMM; out[v] = sum_{e: dst[e]=v} w[e] * x[src[e]]."""
    src, dst, w = _pad_edges(src, dst, w, block_e)
    e = src.shape[0]
    d = x.shape[1]
    nblk = e // block_e

    def kernel(src_ref, dst_ref, w_ref, x_ref, o_ref):
        i = pl.program_id(0)

        @pl.when(i == 0)
        def _init():
            o_ref[...] = jnp.zeros_like(o_ref)

        s = src_ref[...]
        t = dst_ref[...]
        ww = w_ref[...]
        msgs = x_ref[s, :] * ww[:, None]
        o_ref[...] = o_ref[...].at[t].add(msgs)

    return pl.pallas_call(
        kernel,
        grid=(nblk,),
        in_specs=[
            pl.BlockSpec((block_e,), lambda i: (i,)),
            pl.BlockSpec((block_e,), lambda i: (i,)),
            pl.BlockSpec((block_e,), lambda i: (i,)),
            pl.BlockSpec((x.shape[0], d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((n_out, d), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((n_out, d), x.dtype),
        interpret=True,
    )(src, dst, w, x)


def rowtile_pack(src, dst, w, n_out, tile_rows):
    """Build-time packing for ``spmm_rowtile`` (numpy, host side).

    Sorts edges by destination, splits destinations into row tiles of
    ``tile_rows`` rows, pads every tile's edge segment to the max segment
    length.  Returns (src_t, dstloc_t, w_t) of shape [ntiles, cap] where
    dstloc is the destination row *local to the tile*.  Padded entries
    have w == 0 and local row 0.
    """
    src = np.asarray(src)
    dst = np.asarray(dst)
    w = np.asarray(w)
    order = np.argsort(dst, kind="stable")
    src, dst, w = src[order], dst[order], w[order]
    ntiles = _cdiv(n_out, tile_rows)
    tile_of = dst // tile_rows
    counts = np.bincount(tile_of, minlength=ntiles)
    cap = max(int(counts.max(initial=0)), 1)
    src_t = np.zeros((ntiles, cap), np.int32)
    dstloc_t = np.zeros((ntiles, cap), np.int32)
    w_t = np.zeros((ntiles, cap), np.float32)
    starts = np.concatenate([[0], np.cumsum(counts)])
    for t in range(ntiles):
        lo, hi = starts[t], starts[t + 1]
        n = hi - lo
        src_t[t, :n] = src[lo:hi]
        dstloc_t[t, :n] = dst[lo:hi] - t * tile_rows
        w_t[t, :n] = w[lo:hi]
    return src_t, dstloc_t, w_t


def spmm_rowtile(src_t, dstloc_t, w_t, x, n_out, tile_rows):
    """Row-tiled SpMM over pre-packed edges (see ``rowtile_pack``).

    Each grid step writes one disjoint [tile_rows, d] output tile; the
    gather of x rows is the only irregular access.  VMEM footprint per
    step: tile_rows*d (out) + cap*d (messages) + cap*3 (edges).
    """
    ntiles, cap = src_t.shape
    d = x.shape[1]
    padded_rows = ntiles * tile_rows

    def kernel(src_ref, dstloc_ref, w_ref, x_ref, o_ref):
        s = src_ref[0]
        dl = dstloc_ref[0]
        ww = w_ref[0]
        msgs = x_ref[s, :] * ww[:, None]
        o_ref[...] = jnp.zeros_like(o_ref).at[dl].add(msgs)

    out = pl.pallas_call(
        kernel,
        grid=(ntiles,),
        in_specs=[
            pl.BlockSpec((1, cap), lambda i: (i, 0)),
            pl.BlockSpec((1, cap), lambda i: (i, 0)),
            pl.BlockSpec((1, cap), lambda i: (i, 0)),
            pl.BlockSpec((x.shape[0], d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tile_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((padded_rows, d), x.dtype),
        interpret=True,
    )(src_t, dstloc_t, w_t, x)
    return out[:n_out]


def spmm_mean(src, dst, x, n_out, block_e=4096):
    """Fused SpMM_MEAN kernel: accumulates sums and in-degrees across edge
    blocks, divides on the final grid step (grid is sequential)."""
    w = jnp.ones((src.shape[0],), x.dtype)
    src, dst, w = _pad_edges(src, dst, w, block_e)
    e = src.shape[0]
    d = x.shape[1]
    nblk = e // block_e

    def kernel(src_ref, dst_ref, w_ref, x_ref, o_ref, deg_ref):
        i = pl.program_id(0)

        @pl.when(i == 0)
        def _init():
            o_ref[...] = jnp.zeros_like(o_ref)
            deg_ref[...] = jnp.zeros_like(deg_ref)

        s = src_ref[...]
        t = dst_ref[...]
        ww = w_ref[...]
        msgs = x_ref[s, :] * ww[:, None]
        o_ref[...] = o_ref[...].at[t].add(msgs)
        deg_ref[...] = deg_ref[...].at[t].add(ww)

        @pl.when(i == nblk - 1)
        def _fin():
            o_ref[...] = o_ref[...] / jnp.maximum(deg_ref[...], 1.0)[:, None]

    out, _ = pl.pallas_call(
        kernel,
        grid=(nblk,),
        in_specs=[
            pl.BlockSpec((block_e,), lambda i: (i,)),
            pl.BlockSpec((block_e,), lambda i: (i,)),
            pl.BlockSpec((block_e,), lambda i: (i,)),
            pl.BlockSpec((x.shape[0], d), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((n_out, d), lambda i: (0, 0)),
            pl.BlockSpec((n_out,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_out, d), x.dtype),
            jax.ShapeDtypeStruct((n_out,), x.dtype),
        ],
        interpret=True,
    )(src, dst, w, x)
    return out
