"""L2: the GNN op catalog.

The Rust coordinator (L3) performs manual per-op backprop: every forward
layer, every fused (ReLU-mask + SpMM-transpose) backward op, every loss,
Adam update and row-norm reduction is a *separate* jitted jax function that
``aot.py`` lowers to one HLO-text executable.  This file defines those
functions with shapes baked per dataset config, plus the configs themselves.

Why per-op executables?  RSC's contribution is a *dispatch policy*: which
backward-SpMM variant (exact, or a top-k-sampled edge bucket) runs at each
layer each step is decided at runtime by the greedy allocator + cache +
switching schedule.  Static-shape AOT compilation then requires one
executable per (dims, edge-capacity bucket) — the bucket ladder below.

All sparse ops share the edge-list convention of ``kernels/ref.py``; the
approximated ops are the *same* computation over a smaller, padded edge
array (padding has w == 0), so a bucket executable is exact for whatever
edge subset the coordinator feeds it.

Models (paper Section 6.1):
  GCN      H' = relu(SpMM(A_hat, H W))                     (Eq. 1)
  SAGE     H' = relu(H W1 + SpMM_MEAN(A, H) W2)            (Eq. 6)
  GCNII    H' = relu(((1-a) SpMM(A_hat,H) + a H0)((1-b_l)I + b_l W))
  GraphSAINT = SAGE backbone on random-walk subgraphs (padded to caps).

The backward op that RSC approximates is always the SpMM against the
transposed adjacency (Section 3.1): nabla_in = SpMM(A^T, nabla_out-ish).
"""

import dataclasses
import math
from typing import Any, Callable

import jax
import jax.numpy as jnp

from .kernels import ref

# Edge-capacity bucket ladder: fractions of the full edge count.  The
# coordinator picks the smallest bucket >= the sampled edge count, so
# wall-clock cost of the approximated op scales with retained edges.
BUCKET_FRACTIONS = (
    1 / 16,
    1 / 8,
    3 / 16,
    1 / 4,
    3 / 8,
    1 / 2,
    3 / 4,
    1.0,
)


def bucket_caps(m_edges: int) -> list:
    caps = sorted({max(1, math.ceil(f * m_edges)) for f in BUCKET_FRACTIONS})
    if caps[-1] != m_edges:
        caps[-1] = m_edges
    return caps


@dataclasses.dataclass(frozen=True)
class GraphShape:
    """A (node-count, edge-count) pair with its bucket ladder."""

    v: int
    m: int  # directed edges incl. self-loops

    @property
    def caps(self):
        return bucket_caps(self.m)


@dataclasses.dataclass(frozen=True)
class DatasetCfg:
    """Mirrors rust/src/data/synth.rs — single source of truth is checked
    at artifact-load time (rust asserts manifest dims match)."""

    name: str
    v: int
    e: int  # undirected-expanded directed edges, WITHOUT self-loops
    d_in: int
    d_h: int
    n_class: int
    multilabel: bool
    layers: int = 3
    gcnii_layers: int = 4
    gcnii_alpha: float = 0.1
    gcnii_lambda: float = 0.5
    # APPNP: K weight-free propagation steps at teleport alpha
    appnp_layers: int = 8
    appnp_alpha: float = 0.1
    # GIN epsilon (self-term weight 1 + eps, folded into the sum matrix
    # on the rust side; GIN reuses the gcn_fwd executables)
    gin_eps: float = 0.0
    # GraphSAINT padded-subgraph caps (0 = no saint ops for this dataset)
    saint_v: int = 0
    saint_m: int = 0

    @property
    def full(self) -> GraphShape:
        return GraphShape(self.v, self.e + self.v)  # + self-loops

    @property
    def saint(self) -> GraphShape:
        return GraphShape(self.saint_v, self.saint_m)


# Scaled-down synthetic stand-ins for Reddit / Yelp / ogbn-proteins /
# ogbn-products (see DESIGN.md Substitutions).  Edge counts are exact:
# the rust SBM generator emits exactly `e` directed edges.
DATASETS = {
    "reddit-sim": DatasetCfg(
        name="reddit-sim", v=6000, e=150000, d_in=64, d_h=64, n_class=16,
        multilabel=False, saint_v=1536, saint_m=24576,
    ),
    "yelp-sim": DatasetCfg(
        name="yelp-sim", v=8000, e=80000, d_in=64, d_h=64, n_class=20,
        multilabel=True, saint_v=2048, saint_m=16384,
    ),
    "proteins-sim": DatasetCfg(
        name="proteins-sim", v=4000, e=200000, d_in=32, d_h=64, n_class=8,
        multilabel=True,
    ),
    "products-sim": DatasetCfg(
        name="products-sim", v=20000, e=400000, d_in=64, d_h=64, n_class=16,
        multilabel=False, saint_v=4096, saint_m=49152,
    ),
}

# A tiny config for fast tests / CI.
DATASETS["tiny"] = DatasetCfg(
    name="tiny", v=128, e=1024, d_in=16, d_h=16, n_class=4,
    multilabel=False, saint_v=64, saint_m=256,
)


@dataclasses.dataclass
class OpSpec:
    """One AOT executable: a jax function + example input shapes."""

    name: str
    fn: Callable[..., Any]
    args: list  # of jax.ShapeDtypeStruct
    meta: dict


def _f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def _i32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def _edges(m):
    return [_i32(m), _i32(m), _f32(m)]


# --------------------------------------------------------------------------
# Forward ops
# --------------------------------------------------------------------------


def gcn_fwd_fn(v, relu):
    def fn(h, w, src, dst, ew):
        j = ref.matmul_ref(h, w)
        p = ref.spmm_ref(src, dst, ew, j, v)
        return (ref.relu_ref(p) if relu else p,)

    return fn


def sage_fwd_fn(v, relu):
    def fn(h, w1, w2, src, dst, ew):
        m = ref.spmm_ref(src, dst, ew, h, v)  # mean weights baked into ew
        p = ref.matmul_ref(h, w1) + ref.matmul_ref(m, w2)
        return ((ref.relu_ref(p) if relu else p), m)

    return fn


def gcnii_fwd_fn(v, alpha, beta):
    def fn(h, h0, w, src, dst, ew):
        p = ref.spmm_ref(src, dst, ew, h, v)
        u = (1.0 - alpha) * p + alpha * h0
        z = (1.0 - beta) * u + beta * ref.matmul_ref(u, w)
        return (ref.relu_ref(z), u)

    return fn


def dense_fwd_fn(relu):
    def fn(x, w):
        p = ref.matmul_ref(x, w)
        return (ref.relu_ref(p) if relu else p,)

    return fn


def appnp_fwd_fn(v, alpha):
    """One APPNP power step: z' = (1-a) SpMM(A_hat, z) + a h0."""

    def fn(z, h0, src, dst, ew):
        p = ref.spmm_ref(src, dst, ew, z, v)
        return ((1.0 - alpha) * p + alpha * h0,)

    return fn


# --------------------------------------------------------------------------
# Backward ops.  The spmm_bwd_* family is THE op RSC approximates: it runs
# over whatever (possibly sampled + padded) transposed edge list the
# coordinator dispatches, at one of the bucket capacities.
# --------------------------------------------------------------------------


def spmm_bwd_mask_fn(v):
    """Fused ReLU-backward + SpMM^T: gj = SpMM(edges, g .* [h_out>0])."""

    def fn(h_out, g_out, src, dst, ew):
        gp = ref.relu_bwd_ref(h_out, g_out)
        return (ref.spmm_ref(src, dst, ew, gp, v),)

    return fn


def spmm_bwd_nomask_fn(v):
    """SpMM^T without activation mask (output layer)."""

    def fn(g_out, src, dst, ew):
        return (ref.spmm_ref(src, dst, ew, g_out, v),)

    return fn


def spmm_bwd_acc_fn(v):
    """acc + SpMM^T(g): used by SAGE/GCNII where the input grad is the sum
    of a dense term and the (approximated) sparse term."""

    def fn(acc, g, src, dst, ew):
        return (acc + ref.spmm_ref(src, dst, ew, g, v),)

    return fn


def gcn_bwd_mm_fn():
    """Given gj = d(H W), produce (gw, gh)."""

    def fn(h, gj, w):
        gw = ref.matmul_ref(h.T, gj)
        gh = ref.matmul_ref(gj, w.T)
        return gw, gh

    return fn


def sage_bwd_pre_fn(masked):
    """SAGE backward, dense part.  gp = g .* mask; returns the two weight
    grads, the grad wrt the mean-aggregated m (input to the approximated
    SpMM^T), and the dense partial of the input grad."""

    def fn(h_out, g_out, h, m, w1, w2):
        gp = ref.relu_bwd_ref(h_out, g_out) if masked else g_out
        gw1 = ref.matmul_ref(h.T, gp)
        gw2 = ref.matmul_ref(m.T, gp)
        gm = ref.matmul_ref(gp, w2.T)
        gh_a = ref.matmul_ref(gp, w1.T)
        return gw1, gw2, gm, gh_a

    def fn_nomask(g_out, h, m, w1, w2):
        return fn(None, g_out, h, m, w1, w2)

    if masked:
        return fn
    return fn_nomask


def gcnii_bwd_pre_fn(alpha, beta):
    """GCNII backward, dense part: returns (gw, gp, gh0c) where gp feeds
    the approximated SpMM^T and gh0c accumulates into nabla H0."""

    def fn(h_out, g_out, u, w):
        gz = ref.relu_bwd_ref(h_out, g_out)
        gu = (1.0 - beta) * gz + beta * ref.matmul_ref(gz, w.T)
        gw = beta * ref.matmul_ref(u.T, gz)
        gp = (1.0 - alpha) * gu
        gh0c = alpha * gu
        return gw, gp, gh0c

    return fn


def appnp_bwd_pre_fn(alpha):
    """APPNP backward scales: gp feeds the approximated SpMM^T toward
    z^{k-1}, gh0c accumulates into nabla h0."""

    def fn(g):
        return (1.0 - alpha) * g, alpha * g

    return fn


def dense_bwd_fn(masked):
    def fn(x, out, g, w):
        gp = ref.relu_bwd_ref(out, g) if masked else g
        gw = ref.matmul_ref(x.T, gp)
        gx = ref.matmul_ref(gp, w.T)
        return gw, gx

    def fn_nomask(x, g, w):
        return fn(x, None, g, w)

    if masked:
        return fn
    return fn_nomask


def add_fn():
    def fn(a, b):
        return (a + b,)

    return fn


def loss_softmax_fn():
    def fn(logits, labels, mask):
        return ref.softmax_xent_ref(logits, labels, mask)

    return fn


def loss_bce_fn():
    def fn(logits, labels, mask):
        return ref.bce_logits_ref(logits, labels, mask)

    return fn


def adam_fn():
    def fn(w, m, v, g, t, lr):
        return ref.adam_ref(w, m, v, g, t, lr)

    return fn


def row_norms_fn():
    def fn(g):
        return (ref.row_norms_ref(g),)

    return fn


# --------------------------------------------------------------------------
# Catalog assembly
# --------------------------------------------------------------------------


def gcnii_beta(cfg: DatasetCfg, layer: int) -> float:
    """beta_l = log(lambda/l + 1) (Chen et al., 2020); layer is 1-based."""
    return math.log(cfg.gcnii_lambda / layer + 1.0)


def _fwd_ops(cfg: DatasetCfg, g: GraphShape, prefix: str) -> list:
    """Forward ops for one graph shape (full graph or SAINT subgraph)."""
    v, m = g.v, g.m
    dims = [cfg.d_in] + [cfg.d_h] * (cfg.layers - 1) + [cfg.n_class]
    ops = []
    seen = set()

    def emit(name, fn, args, **meta):
        if name in seen:
            return
        seen.add(name)
        ops.append(OpSpec(name, fn, args, dict(meta)))

    # GCN + SAGE per-layer forwards (shared across layers w/ equal dims)
    for l in range(cfg.layers):
        din, dout = dims[l], dims[l + 1]
        relu = l < cfg.layers - 1
        tag = f"{din}x{dout}_{'relu' if relu else 'lin'}"
        emit(
            f"{prefix}gcn_fwd_{tag}",
            gcn_fwd_fn(v, relu),
            [_f32(v, din), _f32(din, dout)] + _edges(m),
            kind="gcn_fwd", din=din, dout=dout, relu=relu, cap=m,
        )
        emit(
            f"{prefix}sage_fwd_{tag}",
            sage_fwd_fn(v, relu),
            [_f32(v, din), _f32(din, dout), _f32(din, dout)] + _edges(m),
            kind="sage_fwd", din=din, dout=dout, relu=relu, cap=m,
        )
    # GCNII stack: in-proj, L propagation layers at d_h, out-proj
    emit(
        f"{prefix}dense_fwd_{cfg.d_in}x{cfg.d_h}_relu",
        dense_fwd_fn(True),
        [_f32(v, cfg.d_in), _f32(cfg.d_in, cfg.d_h)],
        kind="dense_fwd", din=cfg.d_in, dout=cfg.d_h, relu=True,
    )
    emit(
        f"{prefix}dense_fwd_{cfg.d_h}x{cfg.n_class}_lin",
        dense_fwd_fn(False),
        [_f32(v, cfg.d_h), _f32(cfg.d_h, cfg.n_class)],
        kind="dense_fwd", din=cfg.d_h, dout=cfg.n_class, relu=False,
    )
    for l in range(1, cfg.gcnii_layers + 1):
        emit(
            f"{prefix}gcnii_fwd_{cfg.d_h}_l{l}",
            gcnii_fwd_fn(v, cfg.gcnii_alpha, gcnii_beta(cfg, l)),
            [_f32(v, cfg.d_h), _f32(v, cfg.d_h), _f32(cfg.d_h, cfg.d_h)]
            + _edges(m),
            kind="gcnii_fwd", d=cfg.d_h, layer=l, cap=m,
            alpha=cfg.gcnii_alpha, beta=gcnii_beta(cfg, l),
        )
    # APPNP: one shared power-step executable for all K iterations
    emit(
        f"{prefix}appnp_fwd_{cfg.n_class}",
        appnp_fwd_fn(v, cfg.appnp_alpha),
        [_f32(v, cfg.n_class), _f32(v, cfg.n_class)] + _edges(m),
        kind="appnp_fwd", d=cfg.n_class, cap=m, alpha=cfg.appnp_alpha,
    )
    return ops


def _bwd_ops(cfg: DatasetCfg, g: GraphShape, prefix: str) -> list:
    v = g.v
    dims = [cfg.d_in] + [cfg.d_h] * (cfg.layers - 1) + [cfg.n_class]
    ops = []
    seen = set()

    def emit(name, fn, args, **meta):
        if name in seen:
            return
        seen.add(name)
        ops.append(OpSpec(name, fn, args, dict(meta)))

    # The approximated family: one executable per (dim, variant, cap).
    # Backward-SpMM grads only ever have width d_h or n_class (layer-1
    # inputs never need grads — Appendix A.3).
    bwd_dims = sorted({cfg.d_h, cfg.n_class})
    for d in bwd_dims:
        for cap in g.caps:
            emit(
                f"{prefix}spmm_bwd_mask_{d}_cap{cap}",
                spmm_bwd_mask_fn(v),
                [_f32(v, d), _f32(v, d)] + _edges(cap),
                kind="spmm_bwd_mask", d=d, cap=cap,
            )
            emit(
                f"{prefix}spmm_bwd_nomask_{d}_cap{cap}",
                spmm_bwd_nomask_fn(v),
                [_f32(v, d)] + _edges(cap),
                kind="spmm_bwd_nomask", d=d, cap=cap,
            )
            emit(
                f"{prefix}spmm_bwd_acc_{d}_cap{cap}",
                spmm_bwd_acc_fn(v),
                [_f32(v, d), _f32(v, d)] + _edges(cap),
                kind="spmm_bwd_acc", d=d, cap=cap,
            )
    # Dense backward pieces
    for l in range(cfg.layers):
        din, dout = dims[l], dims[l + 1]
        emit(
            f"{prefix}gcn_bwd_mm_{din}x{dout}",
            gcn_bwd_mm_fn(),
            [_f32(v, din), _f32(v, dout), _f32(din, dout)],
            kind="gcn_bwd_mm", din=din, dout=dout,
        )
        masked = l < cfg.layers - 1
        if masked:
            emit(
                f"{prefix}sage_bwd_pre_mask_{din}x{dout}",
                sage_bwd_pre_fn(True),
                [_f32(v, dout), _f32(v, dout), _f32(v, din), _f32(v, din),
                 _f32(din, dout), _f32(din, dout)],
                kind="sage_bwd_pre_mask", din=din, dout=dout,
            )
        else:
            emit(
                f"{prefix}sage_bwd_pre_nomask_{din}x{dout}",
                sage_bwd_pre_fn(False),
                [_f32(v, dout), _f32(v, din), _f32(v, din),
                 _f32(din, dout), _f32(din, dout)],
                kind="sage_bwd_pre_nomask", din=din, dout=dout,
            )
    for l in range(1, cfg.gcnii_layers + 1):
        emit(
            f"{prefix}gcnii_bwd_pre_{cfg.d_h}_l{l}",
            gcnii_bwd_pre_fn(cfg.gcnii_alpha, gcnii_beta(cfg, l)),
            [_f32(v, cfg.d_h)] * 3 + [_f32(cfg.d_h, cfg.d_h)],
            kind="gcnii_bwd_pre", d=cfg.d_h, layer=l,
            alpha=cfg.gcnii_alpha, beta=gcnii_beta(cfg, l),
        )
    emit(
        f"{prefix}dense_bwd_mask_{cfg.d_in}x{cfg.d_h}",
        dense_bwd_fn(True),
        [_f32(v, cfg.d_in), _f32(v, cfg.d_h), _f32(v, cfg.d_h),
         _f32(cfg.d_in, cfg.d_h)],
        kind="dense_bwd_mask", din=cfg.d_in, dout=cfg.d_h,
    )
    emit(
        f"{prefix}dense_bwd_nomask_{cfg.d_h}x{cfg.n_class}",
        dense_bwd_fn(False),
        [_f32(v, cfg.d_h), _f32(v, cfg.n_class), _f32(cfg.d_h, cfg.n_class)],
        kind="dense_bwd_nomask", din=cfg.d_h, dout=cfg.n_class,
    )
    emit(
        f"{prefix}appnp_bwd_pre_{cfg.n_class}",
        appnp_bwd_pre_fn(cfg.appnp_alpha),
        [_f32(v, cfg.n_class)],
        kind="appnp_bwd_pre", d=cfg.n_class, alpha=cfg.appnp_alpha,
    )
    # Elementwise add (grad accumulation), losses, row norms
    for d in sorted({cfg.d_h, cfg.n_class}):
        emit(f"{prefix}add_{d}", add_fn(), [_f32(v, d), _f32(v, d)],
             kind="add", d=d)
        emit(f"{prefix}row_norms_{d}", row_norms_fn(), [_f32(v, d)],
             kind="row_norms", d=d)
    if cfg.multilabel:
        emit(
            f"{prefix}loss_bce",
            loss_bce_fn(),
            [_f32(v, cfg.n_class), _f32(v, cfg.n_class), _f32(v)],
            kind="loss_bce", c=cfg.n_class,
        )
    else:
        emit(
            f"{prefix}loss_softmax",
            loss_softmax_fn(),
            [_f32(v, cfg.n_class), _i32(v), _f32(v)],
            kind="loss_softmax", c=cfg.n_class,
        )
    return ops


def _adam_ops(cfg: DatasetCfg) -> list:
    """Adam is per-weight-shape; graph-independent."""
    dims = [cfg.d_in] + [cfg.d_h] * (cfg.layers - 1) + [cfg.n_class]
    shapes = set()
    for l in range(cfg.layers):
        shapes.add((dims[l], dims[l + 1]))
    shapes.add((cfg.d_in, cfg.d_h))
    shapes.add((cfg.d_h, cfg.d_h))
    shapes.add((cfg.d_h, cfg.n_class))
    ops = []
    for (r, c) in sorted(shapes):
        ops.append(
            OpSpec(
                f"adam_{r}x{c}",
                adam_fn(),
                [_f32(r, c)] * 4 + [_f32(), _f32()],
                {"kind": "adam", "rows": r, "cols": c},
            )
        )
    return ops


def _fwd_cap_ops(cfg: DatasetCfg, g: GraphShape) -> list:
    """Forward GCN ops at reduced edge caps — used only by the Table 1
    experiment (approximating the *forward* pass, which the paper shows
    is catastrophically biased)."""
    v = g.v
    dims = [cfg.d_in] + [cfg.d_h] * (cfg.layers - 1) + [cfg.n_class]
    ops = []
    seen = set()
    for l in range(cfg.layers):
        din, dout = dims[l], dims[l + 1]
        relu = l < cfg.layers - 1
        for cap in g.caps[:-1]:  # full cap already emitted by _fwd_ops
            name = f"gcn_fwd_{din}x{dout}_{'relu' if relu else 'lin'}_cap{cap}"
            if name in seen:
                continue
            seen.add(name)
            ops.append(
                OpSpec(
                    name,
                    gcn_fwd_fn(v, relu),
                    [_f32(v, din), _f32(din, dout)] + _edges(cap),
                    {"kind": "gcn_fwd", "din": din, "dout": dout,
                     "relu": relu, "cap": cap},
                )
            )
    return ops


def build_catalog(cfg: DatasetCfg, fwd_caps: bool = False) -> list:
    """Every executable for one dataset: full-batch ops, optional SAINT
    subgraph ops, Adam, and (optionally) reduced-cap forward ops."""
    ops = []
    ops += _fwd_ops(cfg, cfg.full, "")
    ops += _bwd_ops(cfg, cfg.full, "")
    if cfg.saint_v > 0:
        ops += _fwd_ops(cfg, cfg.saint, "saint_")
        ops += _bwd_ops(cfg, cfg.saint, "saint_")
    ops += _adam_ops(cfg)
    if fwd_caps:
        ops += _fwd_cap_ops(cfg, cfg.full)
    return ops
