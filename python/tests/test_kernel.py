# pytest: Pallas kernels vs pure-jnp oracles — the CORE correctness
# signal for L1.  Hypothesis sweeps shapes, densities and seeds.
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import matmul as km
from compile.kernels import ref
from compile.kernels import spmm as ks

settings.register_profile("kernels", max_examples=25, deadline=None)
settings.load_profile("kernels")


def rand_graph(rng, v, e, d):
    src = jnp.asarray(rng.integers(0, v, e), jnp.int32)
    dst = jnp.asarray(rng.integers(0, v, e), jnp.int32)
    w = jnp.asarray(rng.normal(size=e), jnp.float32)
    x = jnp.asarray(rng.normal(size=(v, d)), jnp.float32)
    return src, dst, w, x


@given(
    v=st.integers(2, 60),
    e=st.integers(1, 300),
    d=st.integers(1, 9),
    seed=st.integers(0, 2**31),
    block=st.sampled_from([16, 64, 256]),
)
def test_spmm_edgeblock_matches_ref(v, e, d, seed, block):
    rng = np.random.default_rng(seed)
    src, dst, w, x = rand_graph(rng, v, e, d)
    want = ref.spmm_ref(src, dst, w, x, v)
    got = ks.spmm_edgeblock(src, dst, w, x, v, block_e=block)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4)


@given(
    v=st.integers(2, 60),
    e=st.integers(1, 300),
    d=st.integers(1, 9),
    seed=st.integers(0, 2**31),
    tile=st.sampled_from([4, 16, 32]),
)
def test_spmm_rowtile_matches_ref(v, e, d, seed, tile):
    rng = np.random.default_rng(seed)
    src, dst, w, x = rand_graph(rng, v, e, d)
    want = ref.spmm_ref(src, dst, w, x, v)
    st_, dl, wt = ks.rowtile_pack(src, dst, w, v, tile)
    got = ks.spmm_rowtile(
        jnp.asarray(st_), jnp.asarray(dl), jnp.asarray(wt), x, v, tile
    )
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4)


@given(
    v=st.integers(2, 50),
    e=st.integers(1, 200),
    d=st.integers(1, 8),
    seed=st.integers(0, 2**31),
)
def test_spmm_mean_matches_ref(v, e, d, seed):
    rng = np.random.default_rng(seed)
    src, dst, _, x = rand_graph(rng, v, e, d)
    want = ref.spmm_mean_ref(src, dst, x, v)
    got = ks.spmm_mean(src, dst, x, v, block_e=64)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4)


@given(
    m=st.integers(1, 70),
    k=st.integers(1, 70),
    n=st.integers(1, 70),
    seed=st.integers(0, 2**31),
)
def test_matmul_matches_ref(m, k, n, seed):
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(k, n)), jnp.float32)
    got = km.matmul(a, b, bm=32, bn=32, bk=32)
    want = ref.matmul_ref(a, b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)


@given(
    m=st.integers(1, 80),
    d=st.integers(1, 16),
    seed=st.integers(0, 2**31),
)
def test_row_norms_matches_ref(m, d, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(m, d)), jnp.float32)
    got = km.row_norms(x, block_rows=16)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(ref.row_norms_ref(x)), atol=1e-5
    )


def test_spmm_padded_edges_are_exact():
    """Padding convention: w=0 edges must not change the result — the
    bucket executables rely on this."""
    rng = np.random.default_rng(0)
    src, dst, w, x = rand_graph(rng, 20, 100, 5)
    base = ref.spmm_ref(src, dst, w, x, 20)
    pad = 37
    src_p = jnp.concatenate([src, jnp.zeros(pad, jnp.int32)])
    dst_p = jnp.concatenate([dst, jnp.zeros(pad, jnp.int32)])
    w_p = jnp.concatenate([w, jnp.zeros(pad, jnp.float32)])
    for fn in [
        lambda: ref.spmm_ref(src_p, dst_p, w_p, x, 20),
        lambda: ks.spmm_edgeblock(src_p, dst_p, w_p, x, 20, block_e=32),
    ]:
        np.testing.assert_allclose(np.asarray(fn()), np.asarray(base), atol=1e-5)


def test_approx_spmm_keep_mask_semantics():
    """approx_spmm_ref(keep) == spmm over only the edges with src in keep —
    the column-row selection oracle (Section 3.2)."""
    rng = np.random.default_rng(1)
    v = 15
    src, dst, w, x = rand_graph(rng, v, 80, 4)
    keep = jnp.asarray(rng.integers(0, 2, v).astype(bool))
    got = ref.approx_spmm_ref(src, dst, w, x, v, keep)
    mask = np.asarray(keep)[np.asarray(src)]
    want = ref.spmm_ref(
        src[mask], dst[mask], w[mask], x, v
    )
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


def test_rowtile_pack_invariants():
    rng = np.random.default_rng(3)
    v, e = 30, 200
    src = rng.integers(0, v, e).astype(np.int32)
    dst = rng.integers(0, v, e).astype(np.int32)
    w = rng.normal(size=e).astype(np.float32)
    tile = 8
    st_, dl, wt = ks.rowtile_pack(src, dst, w, v, tile)
    ntiles = (v + tile - 1) // tile
    assert st_.shape[0] == ntiles
    # every local dst within tile bounds; padded entries have w == 0
    assert (dl >= 0).all() and (dl < tile).all()
    # total non-padded weight count equals e (assuming no zero weights drawn)
    assert (wt != 0).sum() == (w != 0).sum()


def test_losses_match_jax_autodiff():
    """softmax/bce refs must match jax.grad of the loss — these lowered ops
    ARE the training gradient source."""
    rng = np.random.default_rng(5)
    v, c = 12, 5
    logits = jnp.asarray(rng.normal(size=(v, c)), jnp.float32)
    labels = jnp.asarray(rng.integers(0, c, v), jnp.int32)
    mask = jnp.asarray((rng.random(v) > 0.3).astype(np.float32))

    def loss_fn(lg):
        return ref.softmax_xent_ref(lg, labels, mask)[0]

    want = jax.grad(loss_fn)(logits)
    _, got = ref.softmax_xent_ref(logits, labels, mask)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)

    ml = jnp.asarray(rng.integers(0, 2, (v, c)).astype(np.float32))

    def bce_fn(lg):
        return ref.bce_logits_ref(lg, ml, mask)[0]

    want = jax.grad(bce_fn)(logits)
    _, got = ref.bce_logits_ref(logits, ml, mask)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


def test_adam_matches_optax_formula():
    rng = np.random.default_rng(6)
    w = jnp.asarray(rng.normal(size=(4, 3)), jnp.float32)
    m = jnp.zeros_like(w)
    v = jnp.zeros_like(w)
    g = jnp.asarray(rng.normal(size=(4, 3)), jnp.float32)
    w2, m2, v2 = ref.adam_ref(w, m, v, g, 1.0, 0.1)
    # first step with zero state: update ~= -lr * sign-ish(g)
    np.testing.assert_allclose(np.asarray(m2), 0.1 * np.asarray(g), atol=1e-6)
    step = np.asarray(w2 - w)
    assert (np.sign(step) == -np.sign(np.asarray(g))).all()
