# pytest: the AOT path — HLO text emission, manifest schema, and a
# round-trip execution of lowered modules through XLA from python (the
# rust loader is exercised by `cargo test`).
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model
from compile.kernels import ref


def test_hlo_text_emission_and_reexecution(tmp_path):
    cfg = model.DATASETS["tiny"]
    op = next(
        o for o in model.build_catalog(cfg) if o.name.startswith("spmm_bwd_nomask_16_cap")
    )
    text, entry = aot.lower_op(op)
    assert text.startswith("HloModule")
    assert entry["inputs"][0]["dtype"] == "f32"
    assert entry["meta"]["kind"] == "spmm_bwd_nomask"
    # the text parses back into an executable computation
    from jax._src.lib import xla_client as xc

    cap = entry["meta"]["cap"]
    v = cfg.v
    rng = np.random.default_rng(0)
    g = rng.normal(size=(v, 16)).astype(np.float32)
    src = rng.integers(0, v, cap).astype(np.int32)
    dst = rng.integers(0, v, cap).astype(np.int32)
    w = rng.normal(size=cap).astype(np.float32)
    want = np.asarray(
        ref.spmm_ref(jnp.asarray(src), jnp.asarray(dst), jnp.asarray(w), jnp.asarray(g), v)
    )
    got = np.asarray(op.fn(jnp.asarray(g), jnp.asarray(src), jnp.asarray(dst), jnp.asarray(w))[0])
    np.testing.assert_allclose(got, want, atol=1e-4)


def test_emit_dataset_writes_manifest(tmp_path):
    cfg = model.DATASETS["tiny"]
    out = tmp_path / "tiny"
    manifest = aot.emit_dataset(cfg, str(out), fwd_caps=False)
    data = json.loads((out / "manifest.json").read_text())
    assert data["dataset"]["v"] == cfg.v
    assert data["dataset"]["m"] == cfg.full.m
    assert data["dataset"]["caps"][-1] == cfg.full.m
    files = {e["file"] for e in data["ops"]}
    for f in files:
        assert (out / f).exists()
    assert len(files) == len(data["ops"])
    assert manifest["dataset"]["name"] == "tiny"


def test_manifest_dims_match_rust_side_expectations():
    """The rust synth.rs table mirrors these numbers; this test pins the
    python side so a unilateral change fails loudly here too."""
    expect = {
        "reddit-sim": (6000, 150000, 64, 64, 16, False),
        "yelp-sim": (8000, 80000, 64, 64, 20, True),
        "proteins-sim": (4000, 200000, 32, 64, 8, True),
        "products-sim": (20000, 400000, 64, 64, 16, False),
        "tiny": (128, 1024, 16, 16, 4, False),
    }
    for name, (v, e, din, dh, c, ml) in expect.items():
        cfg = model.DATASETS[name]
        assert (cfg.v, cfg.e, cfg.d_in, cfg.d_h, cfg.n_class, cfg.multilabel) == (
            v, e, din, dh, c, ml,
        ), name


def test_all_ops_lower_to_hlo_text():
    """Every op in the tiny catalog must lower to parseable HLO text (this
    is the compile-time contract `make artifacts` relies on)."""
    cfg = model.DATASETS["tiny"]
    ops = model.build_catalog(cfg, fwd_caps=False)
    # lowering everything takes ~10s; sample the distinct kinds instead
    seen = {}
    for op in ops:
        seen.setdefault(op.meta["kind"], op)
    assert len(seen) >= 15
    for kind, op in seen.items():
        text, entry = aot.lower_op(op)
        assert text.startswith("HloModule"), kind
        assert len(entry["outputs"]) >= 1, kind
