# pytest: L2 catalog ops — shape contracts, composition against a
# straight-line jnp reference model, and Prop 3.1 (backward-only
# approximation yields unbiased gradients).
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.kernels import ref

CFG = model.DATASETS["tiny"]


def rand_inputs(rng, specs):
    out = []
    for s in specs:
        if s.dtype == jnp.int32:
            hi = max(int(np.prod(s.shape)), 2)
            out.append(jnp.asarray(rng.integers(0, min(hi, 4), s.shape), jnp.int32))
        else:
            out.append(jnp.asarray(rng.normal(size=s.shape), jnp.float32))
    return out


def test_catalog_builds_and_names_unique():
    ops = model.build_catalog(CFG, fwd_caps=True)
    names = [o.name for o in ops]
    assert len(names) == len(set(names))
    assert len(ops) > 100
    kinds = {o.meta["kind"] for o in ops}
    for k in [
        "gcn_fwd", "sage_fwd", "gcnii_fwd", "dense_fwd", "spmm_bwd_mask",
        "spmm_bwd_nomask", "spmm_bwd_acc", "gcn_bwd_mm", "sage_bwd_pre_mask",
        "sage_bwd_pre_nomask", "gcnii_bwd_pre", "dense_bwd_mask",
        "dense_bwd_nomask", "add", "row_norms", "loss_softmax", "adam",
        "appnp_fwd", "appnp_bwd_pre",
    ]:
        assert k in kinds, k


def test_appnp_backward_matches_autodiff():
    """The rust executor's APPNP VJP: dL/dz = (1-a) SpMM^T(g) via the
    spmm_bwd_nomask family, dL/dh0 = sum_k a g_k — check the per-step
    pieces against jax autodiff of the fused forward."""
    rng = np.random.default_rng(7)
    v, c, e, alpha = 10, 3, 24, CFG.appnp_alpha
    src = jnp.asarray(rng.integers(0, v, e), jnp.int32)
    dst = jnp.asarray(rng.integers(0, v, e), jnp.int32)
    ew = jnp.asarray(rng.normal(size=e), jnp.float32)
    z = jnp.asarray(rng.normal(size=(v, c)), jnp.float32)
    h0 = jnp.asarray(rng.normal(size=(v, c)), jnp.float32)
    g = jnp.asarray(rng.normal(size=(v, c)), jnp.float32)

    fwd = model.appnp_fwd_fn(v, alpha)

    def scalar(z, h0):
        return jnp.vdot(fwd(z, h0, src, dst, ew)[0], g)

    gz_ref, gh0_ref = jax.grad(scalar, argnums=(0, 1))(z, h0)
    gp, gh0c = model.appnp_bwd_pre_fn(alpha)(g)
    # gp propagates through the transposed edges (dst/src swapped)
    gz = ref.spmm_ref(dst, src, ew, gp, v)
    np.testing.assert_allclose(np.asarray(gz), np.asarray(gz_ref), atol=1e-5)
    np.testing.assert_allclose(np.asarray(gh0c), np.asarray(gh0_ref), atol=1e-5)


def test_every_op_evaluates_at_example_shapes():
    """eval_shape already ran at lowering; here we actually execute each op
    once on random inputs and check output shapes match the advertised
    shapes."""
    rng = np.random.default_rng(0)
    ops = model.build_catalog(CFG, fwd_caps=False)
    for op in ops:
        args = rand_inputs(rng, op.args)
        out = op.fn(*args)
        if not isinstance(out, (tuple, list)):
            out = (out,)
        shapes = [tuple(np.asarray(o).shape) for o in out]
        want = jax.eval_shape(op.fn, *op.args)
        if not isinstance(want, (tuple, list)):
            want = (want,)
        assert shapes == [tuple(w.shape) for w in want], op.name


def test_bucket_caps_monotone_and_end_at_m():
    caps = model.bucket_caps(1000)
    assert caps == sorted(set(caps))
    assert caps[-1] == 1000
    assert caps[0] >= 1


def test_gcn_fwd_composition_matches_manual():
    rng = np.random.default_rng(1)
    v, din, dout, e = CFG.v, CFG.d_in, CFG.d_h, CFG.full.m
    fn = model.gcn_fwd_fn(v, relu=True)
    h = jnp.asarray(rng.normal(size=(v, din)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(din, dout)), jnp.float32)
    src = jnp.asarray(rng.integers(0, v, e), jnp.int32)
    dst = jnp.asarray(rng.integers(0, v, e), jnp.int32)
    ew = jnp.asarray(rng.normal(size=e), jnp.float32)
    (got,) = fn(h, w, src, dst, ew)
    want = ref.relu_ref(ref.spmm_ref(src, dst, ew, h @ w, v))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-3)


def test_gcn_backward_ops_match_jax_autodiff():
    """The manual backward decomposition (spmm_bwd_mask + gcn_bwd_mm) must
    equal jax.grad of the fused layer."""
    rng = np.random.default_rng(2)
    v, din, dout, e = 30, 8, 6, 90
    h = jnp.asarray(rng.normal(size=(v, din)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(din, dout)), jnp.float32)
    src = jnp.asarray(rng.integers(0, v, e), jnp.int32)
    dst = jnp.asarray(rng.integers(0, v, e), jnp.int32)
    ew = jnp.asarray(rng.normal(size=e), jnp.float32)
    g_out = jnp.asarray(rng.normal(size=(v, dout)), jnp.float32)

    def layer(h, w):
        return ref.relu_ref(ref.spmm_ref(src, dst, ew, h @ w, v))

    h_out = layer(h, w)
    want_gh, want_gw = jax.vjp(layer, h, w)[1](g_out)

    # manual: transposed edges = (src=dst_row, dst=col) of the matrix
    # S[dst,src] — transpose swaps roles.
    gj = model.spmm_bwd_mask_fn(v)(h_out, g_out, dst, src, ew)[0]
    gw, gh = model.gcn_bwd_mm_fn()(h, gj, w)
    np.testing.assert_allclose(np.asarray(gw), np.asarray(want_gw), atol=1e-3)
    np.testing.assert_allclose(np.asarray(gh), np.asarray(want_gh), atol=1e-3)


def test_sage_backward_matches_autodiff():
    rng = np.random.default_rng(3)
    v, din, dout, e = 25, 7, 5, 70
    h = jnp.asarray(rng.normal(size=(v, din)), jnp.float32)
    w1 = jnp.asarray(rng.normal(size=(din, dout)), jnp.float32)
    w2 = jnp.asarray(rng.normal(size=(din, dout)), jnp.float32)
    src = jnp.asarray(rng.integers(0, v, e), jnp.int32)
    dst = jnp.asarray(rng.integers(0, v, e), jnp.int32)
    ew = jnp.asarray(rng.normal(size=e), jnp.float32)
    g_out = jnp.asarray(rng.normal(size=(v, dout)), jnp.float32)

    def layer(h, w1, w2):
        m = ref.spmm_ref(src, dst, ew, h, v)
        return ref.relu_ref(h @ w1 + m @ w2)

    h_out, m = model.sage_fwd_fn(v, relu=True)(h, w1, w2, src, dst, ew)
    want_gh, want_gw1, want_gw2 = jax.vjp(layer, h, w1, w2)[1](g_out)

    gw1, gw2, gm, gh_a = model.sage_bwd_pre_fn(True)(h_out, g_out, h, m, w1, w2)
    (gh,) = model.spmm_bwd_acc_fn(v)(gh_a, gm, dst, src, ew)
    np.testing.assert_allclose(np.asarray(gw1), np.asarray(want_gw1), atol=1e-3)
    np.testing.assert_allclose(np.asarray(gw2), np.asarray(want_gw2), atol=1e-3)
    np.testing.assert_allclose(np.asarray(gh), np.asarray(want_gh), atol=1e-3)


def test_gcnii_backward_matches_autodiff():
    rng = np.random.default_rng(4)
    v, d, e = 20, 6, 60
    alpha, beta = 0.1, model.gcnii_beta(CFG, 2)
    h = jnp.asarray(rng.normal(size=(v, d)), jnp.float32)
    h0 = jnp.asarray(rng.normal(size=(v, d)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(d, d)), jnp.float32)
    src = jnp.asarray(rng.integers(0, v, e), jnp.int32)
    dst = jnp.asarray(rng.integers(0, v, e), jnp.int32)
    ew = jnp.asarray(rng.normal(size=e), jnp.float32)
    g_out = jnp.asarray(rng.normal(size=(v, d)), jnp.float32)

    def layer(h, h0, w):
        p = ref.spmm_ref(src, dst, ew, h, v)
        u = (1 - alpha) * p + alpha * h0
        z = (1 - beta) * u + beta * u @ w
        return ref.relu_ref(z)

    h_out, u = model.gcnii_fwd_fn(v, alpha, beta)(h, h0, w, src, dst, ew)
    want_gh, want_gh0, want_gw = jax.vjp(layer, h, h0, w)[1](g_out)

    gw, gp, gh0c = model.gcnii_bwd_pre_fn(alpha, beta)(h_out, g_out, u, w)
    (gh,) = model.spmm_bwd_nomask_fn(v)(gp, dst, src, ew)
    np.testing.assert_allclose(np.asarray(gw), np.asarray(want_gw), atol=1e-3)
    np.testing.assert_allclose(np.asarray(gh0c), np.asarray(want_gh0), atol=1e-3)
    np.testing.assert_allclose(np.asarray(gh), np.asarray(want_gh), atol=1e-3)


def test_prop31_backward_only_approx_is_unbiased():
    """Proposition 3.1: with an unbiased estimator (Drineas probability
    sampling) applied ONLY in the backward pass, E[grad] == exact grad.
    Monte-Carlo check on a 1-layer GCN."""
    rng = np.random.default_rng(7)
    v, din, dout, e = 12, 5, 3, 50
    h = jnp.asarray(rng.normal(size=(v, din)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(din, dout)), jnp.float32)
    src = jnp.asarray(rng.integers(0, v, e), jnp.int32)
    dst = jnp.asarray(rng.integers(0, v, e), jnp.int32)
    ew = jnp.asarray(rng.normal(size=e), jnp.float32)
    g_out = jnp.asarray(rng.normal(size=(v, dout)), jnp.float32)

    h_out = model.gcn_fwd_fn(v, relu=True)(h, w, src, dst, ew)[0]
    # exact gradient wrt J = H W
    gp = ref.relu_bwd_ref(h_out, g_out)
    exact_gj = ref.spmm_ref(dst, src, ew, gp, v)

    # column-row pair i of A^T = row i of A = edges with dst == i (matrix
    # rows are dst).  p_i ∝ ‖A^T_{:,i}‖‖gp_i‖.
    ew_np = np.asarray(ew)
    dst_np = np.asarray(dst)
    col_norm = np.zeros(v)
    for i in range(v):
        col_norm[i] = math.sqrt(float((ew_np[dst_np == i] ** 2).sum()))
    gp_norm = np.linalg.norm(np.asarray(gp), axis=1)
    scores = col_norm * gp_norm
    p = scores / scores.sum()

    k, trials = 3, 1500
    acc = np.zeros((v, dout), np.float64)
    for _ in range(trials):
        picks = rng.choice(v, size=k, p=p)
        scale = np.zeros(v, np.float32)
        for i in picks:
            scale[i] += 1.0 / (k * p[i])
        ew_scaled = ew * jnp.asarray(scale)[dst]
        approx_gj = ref.spmm_ref(dst, src, ew_scaled, gp, v)
        acc += np.asarray(approx_gj, np.float64)
    mean = acc / trials
    scale_ref = np.abs(np.asarray(exact_gj)).max() + 0.1
    assert np.abs(mean - np.asarray(exact_gj)).max() / scale_ref < 0.12


def test_forward_approx_is_biased_through_relu():
    """The converse of Prop 3.1 (Section 3.1.2): the SAME unbiased
    estimator applied in the FORWARD pass gives biased activations,
    because E[relu(x)] != relu(E[x])."""
    rng = np.random.default_rng(8)
    v, din, dout, e = 10, 4, 3, 40
    h = jnp.asarray(rng.normal(size=(v, din)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(din, dout)), jnp.float32)
    src = jnp.asarray(rng.integers(0, v, e), jnp.int32)
    dst = jnp.asarray(rng.integers(0, v, e), jnp.int32)
    ew = jnp.asarray(rng.normal(size=e), jnp.float32)

    exact = model.gcn_fwd_fn(v, relu=True)(h, w, src, dst, ew)[0]
    j = h @ w
    ew_np = np.asarray(ew)
    dst_np = np.asarray(dst)
    col_norm = np.zeros(v)
    for i in range(v):
        col_norm[i] = math.sqrt(float((ew_np[dst_np == i] ** 2).sum()))
    # here the "rows" of the product are J rows: pair i weights ‖J_i‖
    jn = np.linalg.norm(np.asarray(j), axis=1)
    # forward spmm edges: out[dst] += w x[src]; pair index = src column
    src_np = np.asarray(src)
    col_norm_src = np.zeros(v)
    for i in range(v):
        col_norm_src[i] = math.sqrt(float((ew_np[src_np == i] ** 2).sum()))
    scores = col_norm_src * jn
    p = scores / max(scores.sum(), 1e-9)

    k, trials = 2, 1200
    acc = np.zeros((v, dout), np.float64)
    for _ in range(trials):
        picks = rng.choice(v, size=k, p=p)
        scale = np.zeros(v, np.float32)
        for i in picks:
            scale[i] += 1.0 / (k * p[i])
        ew_scaled = ew * jnp.asarray(scale)[src]
        approx = ref.relu_ref(ref.spmm_ref(src, dst, ew_scaled, j, v))
        acc += np.asarray(approx, np.float64)
    mean = acc / trials
    bias = np.abs(mean - np.asarray(exact)).max()
    scale_ref = np.abs(np.asarray(exact)).max() + 0.1
    # relative bias should be clearly nonzero (vs <0.12 in the bwd test)
    assert bias / scale_ref > 0.2, f"expected visible bias, got {bias / scale_ref}"
